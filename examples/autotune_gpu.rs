//! Transfer to the SECOND target (§4.3): CPU-pre-trained cost model
//! fine-tuned for the GPU platform, per-matrix speedup report.
//!
//!   cargo run --release --example autotune_gpu [-- --op sddmm]

use cognate::config::PlatformId;
use cognate::coordinator::{Pipeline, Scale};
use cognate::kernels::Op;
use cognate::model::ModelDriver;
use cognate::search::{evaluate, oracle_summary};
use cognate::train::train;
use cognate::util::table::Table;
use anyhow::Result;

fn main() -> Result<()> {
    let op = if std::env::args().any(|a| a == "sddmm") { Op::Sddmm } else { Op::Spmm };
    let mut pipe = Pipeline::new(Scale::small())?;
    let target = PlatformId::Gpu;

    let src = pipe.dataset(PlatformId::Cpu, op)?;
    let tgt = pipe.dataset(target, op)?;
    let z_src = pipe.trained_ae(PlatformId::Cpu, "ae", 1)?;
    let z_tgt = pipe.trained_ae(target, "ae", 2)?;

    let (pool, _) = pipe.splits(&src);
    let idx = pipe.pretrain_subset(&src, &pool, pipe.scale.pretrain_matrices);
    let mut driver = ModelDriver::init(pipe.rt.clone(), "cognate", 21)?;
    train(&mut driver, &z_src, &src, &idx, &[], &pipe.scale.pretrain_opts.clone())?;

    let (tpool, eval_idx) = pipe.splits(&tgt);
    let ft: Vec<usize> = tpool.into_iter().take(pipe.scale.finetune_matrices).collect();
    let mut tuned = driver.fork_for_finetune();
    train(&mut tuned, &z_tgt, &tgt, &ft, &[], &pipe.scale.finetune_opts.clone())?;

    let default_index = cognate::config::default_config_index(target);
    let top1 = evaluate(&tuned, &z_tgt, &tgt, &eval_idx, default_index, 1)?;
    let top5 = evaluate(&tuned, &z_tgt, &tgt, &eval_idx, default_index, 5)?;
    let oracle = oracle_summary(&tgt, &eval_idx, default_index);

    let mut t = Table::new(
        &format!("gpu transfer, {} — per-matrix top-5 speedups", op.name()),
        &["matrix", "top5_speedup", "optimal"],
    );
    for e in &top5.per_matrix {
        t.row(vec![e.name.clone(), Table::f(e.speedup), Table::f(e.optimal_speedup)]);
    }
    println!("{}", t.render());
    println!(
        "geomean: top-1 {:.3}x, top-5 {:.3}x, optimal {:.3}x",
        top1.geomean_speedup, top5.geomean_speedup, oracle.geomean_speedup
    );
    Ok(())
}
