//! Tuning-service demo: start the batched auto-tuning server with a
//! quickly fine-tuned model and fire concurrent client requests at it,
//! reporting latency and batching behaviour.
//!
//!   cargo run --release --example serve_demo [-- --shards S] [--metrics-out PATH]
//!
//! `--shards S` (or `COGNATE_SHARDS=S`, default 2) sets the number of
//! batcher shards behind the least-loaded router; each runs its own
//! adaptive linger controller.
//!
//! With `--metrics-out PATH` (or `COGNATE_METRICS_OUT=PATH`), writes
//! the process-global telemetry snapshot as JSON after the run — the
//! server runs in-process, so the snapshot covers train + serve. The
//! verify.sh smoke step uses this to assert `serve.jobs_total` > 0.
//!
//! With `--trace-out PATH` (or `COGNATE_TRACE_OUT=PATH`), drains the
//! span rings into Chrome trace_event JSON after the run — load it in
//! Perfetto or chrome://tracing to see every request's
//! accept → queue → linger → featurize → score → reply tree, tagged
//! with shard and batch ids. The demo samples every request
//! (`COGNATE_TRACE_SAMPLE` overrides).

use cognate::config::PlatformId;
use cognate::coordinator::{serve, Pipeline, Scale};
use cognate::kernels::Op;
use cognate::model::ModelDriver;
use cognate::sparse::gen::{generate, Family};
use cognate::train::{train, TrainOpts};
use anyhow::Result;

fn main() -> Result<()> {
    // Trace every request unless COGNATE_TRACE_SAMPLE says otherwise —
    // a demo run is exactly when you want the full span tree.
    cognate::util::trace::init_from_env(1.0);
    let mut scale = Scale::small();
    scale.pretrain_opts = TrainOpts { epochs: 3, batches_per_epoch: 16, val_matrices: 0, ..TrainOpts::default() };
    scale.ae_steps = 100;
    let mut pipe = Pipeline::new(scale)?;
    let op = Op::Spmm;
    let target = PlatformId::Spade;

    let tgt = pipe.dataset(target, op)?;
    let zenc = pipe.trained_ae(target, "ae", 2)?;
    let (pool, _) = pipe.splits(&tgt);
    let ft: Vec<usize> = pool.into_iter().take(5).collect();
    let mut driver = ModelDriver::init(pipe.rt.clone(), "cognate", 4)?;
    train(&mut driver, &zenc, &tgt, &ft, &[], &pipe.scale.pretrain_opts.clone())?;

    let n_clients = 8;
    let argv: Vec<String> = std::env::args().collect();
    let shards = argv
        .iter()
        .position(|a| a == "--shards")
        .and_then(|i| argv.get(i + 1))
        .and_then(|s| s.parse().ok())
        .or_else(|| std::env::var("COGNATE_SHARDS").ok().and_then(|s| s.parse().ok()))
        .unwrap_or(2usize)
        .max(1);
    let opts = serve::ServeOpts { shards, max_jobs: Some(n_clients), ..serve::ServeOpts::default() };
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let server = std::thread::spawn(move || {
        serve::serve(driver, zenc, target, "127.0.0.1:0", opts, move |a| {
            let _ = addr_tx.send(a);
        })
    });
    let addr = addr_rx.recv()?;
    println!("service up on {addr} ({shards} shards); firing {n_clients} concurrent requests");

    let t0 = std::time::Instant::now();
    let clients: Vec<_> = (0..n_clients)
        .map(|id| {
            std::thread::spawn(move || {
                let fam = [Family::Rmat, Family::PowerLaw, Family::Banded][id % 3];
                let m = generate(fam, 400 + 100 * id, 500, 0.02, id as u64);
                serve::request(addr, id as i64, 5, &m)
            })
        })
        .collect();
    let mut latencies = Vec::new();
    let mut batched = Vec::new();
    for c in clients {
        let resp = c.join().unwrap()?;
        latencies.push(resp.req("latency_ms").as_f64().unwrap());
        batched.push(resp.req("batched_with").as_f64().unwrap());
        println!(
            "  id={} top={} latency={:.1}ms batch={}",
            resp.req("id").as_i64().unwrap(),
            resp.req("top").to_string(),
            resp.req("latency_ms").as_f64().unwrap(),
            resp.req("batched_with").as_f64().unwrap(),
        );
    }
    println!(
        "served {n_clients} requests in {:.1}ms wall; mean latency {:.1}ms; mean batch size {:.1}",
        t0.elapsed().as_secs_f64() * 1e3,
        latencies.iter().sum::<f64>() / latencies.len() as f64,
        batched.iter().sum::<f64>() / batched.len() as f64,
    );
    let _ = server.join().unwrap();

    // Telemetry snapshot: --metrics-out PATH beats COGNATE_METRICS_OUT.
    let metrics_out = argv
        .iter()
        .position(|a| a == "--metrics-out")
        .and_then(|i| argv.get(i + 1).cloned())
        .or_else(|| std::env::var("COGNATE_METRICS_OUT").ok());
    if let Some(path) = metrics_out {
        let snap = cognate::util::metrics::registry().snapshot();
        std::fs::write(&path, format!("{}\n", snap.to_string()))?;
        println!("wrote metrics snapshot: {path}");
    }

    // Chrome-trace export: --trace-out PATH beats COGNATE_TRACE_OUT.
    let trace_out = argv
        .iter()
        .position(|a| a == "--trace-out")
        .and_then(|i| argv.get(i + 1).cloned())
        .or_else(|| std::env::var("COGNATE_TRACE_OUT").ok());
    if let Some(path) = trace_out {
        let n = cognate::util::trace::write_chrome_trace(&path)?;
        println!("wrote chrome trace ({n} spans): {path}");
    }
    Ok(())
}
