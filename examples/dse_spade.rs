//! END-TO-END VALIDATION DRIVER (EXPERIMENTS.md §E2E).
//!
//!   cargo run --release --example dse_spade [-- --scale N]
//!
//! Runs the paper's full SPADE design-space-exploration pipeline on a
//! real (synthetic-collection) workload and prints the Fig-4-shaped
//! headline comparison — zero-shot / no-transfer / WACO+FA / WACO+FM /
//! COGNATE top-1/top-5 / oracle — together with the training loss curve,
//! proving all three layers compose: Rust coordinator + simulators →
//! PJRT-executed JAX/Pallas train & inference artifacts → evaluation.

use cognate::coordinator::{experiments, Pipeline, Scale};
use cognate::kernels::Op;
use anyhow::Result;

fn main() -> Result<()> {
    let scale_arg = std::env::args()
        .skip_while(|a| a != "--scale")
        .nth(1)
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(1);
    let t0 = std::time::Instant::now();
    let mut pipe = Pipeline::new(Scale::scaled(scale_arg))?;

    // Training curve (Fig 6 shape) first: shows the model actually learns.
    let tables = experiments::run(&mut pipe, "fig6")?;
    drop(tables);

    // Headline: every method on SpMM/SPADE (Fig 2 / Fig 4 left).
    experiments::run(&mut pipe, "fig2")?;

    // Landscape-correlation diagnostic (the transfer premise).
    let diag = experiments::correlation_diagnostic(&mut pipe, Op::Spmm)?;
    println!("{}", diag.render());

    println!(
        "dse_spade complete in {:.1}s (scale {scale_arg}); CSVs in results/",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
