//! GNN end-to-end (§4.3): a GraphSAGE-style layer stack built on the
//! *executable* SpMM substrate, with the schedule chosen by a trained
//! COGNATE cost model vs. the default schedule — reporting real
//! wall-clock inference speedup on a 'transient'-scale synthetic graph.
//!
//!   cargo run --release --example gnn_e2e

use cognate::config::{Config, CpuOrder, PlatformId};
use cognate::coordinator::{Pipeline, Scale};
use cognate::kernels::{spmm_scheduled, Op, SpmmSchedule};
use cognate::model::ModelDriver;
use cognate::platform::make_platform;
use cognate::search::{score_all, top_k};
use cognate::sparse::gen::{generate, Family};
use cognate::train::train;
use cognate::util::rng::Rng;
use anyhow::Result;
use std::time::Instant;

/// Map a CPU config onto the executable SpMM schedule.
fn schedule_for(cfg: &Config) -> SpmmSchedule {
    match cfg {
        Config::Cpu(c) => SpmmSchedule {
            i_block: c.i_split,
            k_block: c.k_split,
            outer_k: matches!(c.order, CpuOrder::KOuter | CpuOrder::KJOuter),
        },
        _ => SpmmSchedule::default(),
    }
}

/// One GraphSAGE layer: H' = relu( (A · H) · W ), A row-normalised.
fn sage_layer(a: &cognate::sparse::Csr, h: &[f32], w: &[f32], din: usize, dout: usize, s: SpmmSchedule, agg: &mut [f32], out: &mut [f32]) {
    spmm_scheduled(a, h, din, s, agg);
    // Dense projection + ReLU (plain host matmul — the sparse op is the
    // tunable bottleneck this example measures).
    for r in 0..a.rows {
        for j in 0..dout {
            let mut acc = 0f32;
            for k in 0..din {
                acc += agg[r * din + k] * w[k * dout + j];
            }
            out[r * dout + j] = acc.max(0.0);
        }
    }
}

fn main() -> Result<()> {
    // 'transient'-scale graph, shrunk to keep the demo quick: the paper's
    // matrix has 178,866 nodes / 961,368 nnz; we use a proportional
    // RMAT graph (n=20k, nnz≈110k) with the same density profile.
    let n = 20_000;
    let graph = generate(Family::Rmat, n, n, 110_000.0 / (n as f64 * n as f64), 0xA11);
    let hidden = 64usize; // 3 hidden layers à la GraphSAGE config
    println!("graph: {}x{} nnz={}", graph.rows, graph.cols, graph.nnz());

    // Train a COGNATE model for the CPU platform (source == target here:
    // the GNN runs on the CPU substrate we can actually execute).
    let mut scale = Scale::small();
    scale.pretrain_opts.epochs = 6;
    let mut pipe = Pipeline::new(scale)?;
    let ds = pipe.dataset(PlatformId::Cpu, Op::Spmm)?;
    let zenc = pipe.trained_ae(PlatformId::Cpu, "ae", 3)?;
    let (pool, _) = pipe.splits(&ds);
    let idx = pipe.pretrain_subset(&ds, &pool, pipe.scale.pretrain_matrices);
    let mut driver = ModelDriver::init(pipe.rt.clone(), "cognate", 5)?;
    train(&mut driver, &zenc, &ds, &idx, &[], &pipe.scale.pretrain_opts.clone())?;

    // Ask the model for the best schedule for THIS graph.
    let sim = make_platform(PlatformId::Cpu);
    let costs = sim.eval_all(&graph, Op::Spmm);
    let rec = cognate::coordinator::serve::record_for(&graph, costs, "transient-like");
    let scores = score_all(&driver, &zenc, &ds, &rec, None)?;
    let best = top_k(&scores, 5)
        .into_iter()
        .min_by(|&a, &b| rec.costs[a].partial_cmp(&rec.costs[b]).unwrap())
        .unwrap();
    let tuned_sched = schedule_for(&sim.config(best));
    let default_sched = schedule_for(&sim.config(sim.default_index()));
    println!("default schedule: {default_sched:?}");
    println!("tuned schedule:   {tuned_sched:?} (config #{best})");

    // Run 3-layer GraphSAGE inference under both schedules.
    let mut rng = Rng::new(1);
    let feat: Vec<f32> = (0..n * hidden).map(|_| rng.next_f32() - 0.5).collect();
    let weights: Vec<Vec<f32>> = (0..3)
        .map(|_| (0..hidden * hidden).map(|_| (rng.next_f32() - 0.5) * 0.2).collect())
        .collect();
    let mut time_with = |s: SpmmSchedule| -> (f64, f32) {
        let mut h = feat.clone();
        let mut agg = vec![0f32; n * hidden];
        let mut out = vec![0f32; n * hidden];
        let t0 = Instant::now();
        for w in &weights {
            sage_layer(&graph, &h, w, hidden, hidden, s, &mut agg, &mut out);
            std::mem::swap(&mut h, &mut out);
        }
        (t0.elapsed().as_secs_f64(), h.iter().sum::<f32>())
    };
    // Warm-up then measure best-of-3 for stability.
    let _ = time_with(default_sched);
    let (mut td, mut tt) = (f64::INFINITY, f64::INFINITY);
    let (mut cd, mut ct) = (0f32, 0f32);
    for _ in 0..3 {
        let (t, c) = time_with(default_sched);
        if t < td {
            td = t;
            cd = c;
        }
        let (t, c) = time_with(tuned_sched);
        if t < tt {
            tt = t;
            ct = c;
        }
    }
    assert!((cd - ct).abs() <= 1e-2 * (1.0 + cd.abs()), "numerics must match");
    println!(
        "GraphSAGE 3-layer inference: default {:.1} ms, tuned {:.1} ms → {:.2}x speedup",
        td * 1e3,
        tt * 1e3,
        td / tt
    );
    Ok(())
}
