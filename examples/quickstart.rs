//! Quickstart: the whole COGNATE loop in one file, at micro scale.
//!
//!   cargo run --release --example quickstart
//!
//! 1. generate a small synthetic matrix collection,
//! 2. collect cheap CPU samples + a few expensive SPADE samples
//!    (deterministic simulators stand in for hardware — DESIGN.md),
//! 3. train the latent autoencoder and pre-train the cost model on CPU,
//! 4. few-shot fine-tune on SPADE (5 matrices),
//! 5. pick top-5 configs for an unseen matrix and report the speedup.

use cognate::config::PlatformId;
use cognate::coordinator::{Pipeline, Scale};
use cognate::kernels::Op;
use cognate::model::ModelDriver;
use cognate::platform::make_platform;
use cognate::search::{eval_one, score_all};
use cognate::sparse::gen::{generate, Family};
use cognate::train::{train, TrainOpts};
use anyhow::Result;

fn main() -> Result<()> {
    let mut scale = Scale::small();
    scale.per_cell = 2;
    scale.max_dim = 1024;
    scale.pretrain_matrices = 20;
    scale.pretrain_opts = TrainOpts { epochs: 4, batches_per_epoch: 24, val_matrices: 0, ..TrainOpts::default() };
    scale.finetune_opts = TrainOpts { epochs: 3, batches_per_epoch: 12, val_matrices: 0, ..TrainOpts::default() };
    scale.ae_steps = 150;
    let mut pipe = Pipeline::new(scale)?;
    let op = Op::Spmm;

    println!("== 1/5 collection + datasets (cpu source, spade target)");
    let src = pipe.dataset(PlatformId::Cpu, op)?;
    let tgt = pipe.dataset(PlatformId::Spade, op)?;

    println!("== 2/5 latent autoencoders (§3.3)");
    let z_src = pipe.trained_ae(PlatformId::Cpu, "ae", 1)?;
    let z_tgt = pipe.trained_ae(PlatformId::Spade, "ae", 2)?;

    println!("== 3/5 pre-train on cpu ({} matrices)", pipe.scale.pretrain_matrices);
    let (pool, _) = pipe.splits(&src);
    let idx = pipe.pretrain_subset(&src, &pool, pipe.scale.pretrain_matrices);
    let mut driver = ModelDriver::init(pipe.rt.clone(), "cognate", 7)?;
    train(&mut driver, &z_src, &src, &idx, &[], &pipe.scale.pretrain_opts.clone())?;

    println!("== 4/5 few-shot fine-tune on spade ({} matrices)", pipe.scale.finetune_matrices);
    let (tpool, _) = pipe.splits(&tgt);
    let ft: Vec<usize> = tpool.into_iter().take(pipe.scale.finetune_matrices).collect();
    let mut tuned = driver.fork_for_finetune();
    train(&mut tuned, &z_tgt, &tgt, &ft, &[], &pipe.scale.finetune_opts.clone())?;

    println!("== 5/5 tune an unseen matrix");
    let m = generate(Family::Rmat, 1500, 1500, 0.01, 0xBEE);
    let sim = make_platform(PlatformId::Spade);
    let costs = sim.eval_all(&m, op);
    let rec = cognate::coordinator::serve::record_for(&m, costs, "unseen-rmat");
    let scores = score_all(&tuned, &z_tgt, &tgt, &rec, None)?;
    let e = eval_one(&rec, &scores, sim.default_index(), 5);
    println!(
        "matrix {}x{} (nnz {}): cognate top-5 speedup {:.2}x over the default \
         schedule (exhaustive optimum {:.2}x), chosen {:?}",
        m.rows,
        m.cols,
        m.nnz(),
        e.speedup,
        e.optimal_speedup,
        sim.config(e.chosen_index),
    );
    Ok(())
}
