#!/usr/bin/env bash
# Repo verification gate: build, test, lint, then produce the kernel A/B
# numbers (BENCH_kernels.json at the repo root).
#
# The growth container does not ship the Rust toolchain, so this script
# is the CI entry point — it degrades to a clear error instead of a
# confusing cascade when cargo is absent.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "verify.sh: cargo not found on PATH — install the Rust toolchain" >&2
    echo "           (rustup.rs) or run this from the CI image." >&2
    exit 2
fi

manifest=""
for cand in Cargo.toml rust/Cargo.toml; do
    if [ -f "$cand" ]; then
        manifest="$cand"
        break
    fi
done
if [ -z "$manifest" ]; then
    echo "verify.sh: no Cargo.toml found (expected at repo root or rust/)" >&2
    exit 2
fi

echo "== build (release) =="
cargo build --release --manifest-path "$manifest"

echo "== test =="
cargo test -q --manifest-path "$manifest"

echo "== clippy =="
cargo clippy --all-targets --manifest-path "$manifest" -- -D warnings

echo "== kernel A/B bench → BENCH_kernels.json =="
BENCH_OUT="$(pwd)/BENCH_kernels.json" \
    cargo bench --bench bench_perf_ab --manifest-path "$manifest"

echo "verify.sh: all gates passed"
