#!/usr/bin/env bash
# Repo verification gate: build, test, lint, then produce the kernel A/B
# numbers (BENCH_kernels.json at the repo root).
#
# The growth container does not ship the Rust toolchain, so this script
# is the CI entry point — it degrades to a clear error instead of a
# confusing cascade when cargo is absent.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "verify.sh: cargo not found on PATH — install the Rust toolchain" >&2
    echo "           (rustup.rs) or run this from the CI image." >&2
    exit 2
fi

manifest=""
for cand in Cargo.toml rust/Cargo.toml; do
    if [ -f "$cand" ]; then
        manifest="$cand"
        break
    fi
done
if [ -z "$manifest" ]; then
    echo "verify.sh: no Cargo.toml found (expected at repo root or rust/)" >&2
    exit 2
fi

echo "== build (release) =="
cargo build --release --manifest-path "$manifest"

echo "== test =="
cargo test -q --manifest-path "$manifest"

echo "== clippy =="
cargo clippy --all-targets --manifest-path "$manifest" -- -D warnings

echo "== lint (cognate_lint static analysis) =="
# Dependency-free scanner enforcing the metric canon, macro-aliasing,
# SAFETY-comment, panic-audit, and determinism rules (ROADMAP.md
# "Static analysis"). Exits 1 with file:line: rule: diagnostics on any
# finding. Falls back to the tests/lint.rs gate if bin discovery ever
# differs across manifest layouts.
if cargo run --release --manifest-path "$manifest" --bin cognate_lint -- --help \
    >/dev/null 2>&1; then
    COGNATE_LINT_ROOT="$(pwd)" \
        cargo run --release --manifest-path "$manifest" --bin cognate_lint -- \
        --json "$(pwd)/LINT_report.json"
else
    echo "verify.sh: cognate_lint bin not discoverable — falling back to tests/lint.rs" >&2
    cargo test -q --manifest-path "$manifest" --test lint
fi

echo "== thread sanitizer smoke (optional) =="
# TSan needs nightly + rust-src on x86_64 Linux; degrade with a clear
# message instead of cascading when any piece is missing.
if command -v rustup >/dev/null 2>&1 \
    && rustup toolchain list 2>/dev/null | grep -q nightly \
    && rustup component list --toolchain nightly 2>/dev/null \
        | grep -q 'rust-src (installed)' \
    && [ "$(uname -sm)" = "Linux x86_64" ]; then
    RUSTFLAGS="-Zsanitizer=thread" \
        cargo +nightly test --manifest-path "$manifest" -Zbuild-std \
        --target x86_64-unknown-linux-gnu --test integration_serve \
        -- --test-threads=1
else
    echo "verify.sh: nightly toolchain with rust-src not available on x86_64 Linux —" >&2
    echo "           skipping ThreadSanitizer smoke of tests/integration_serve.rs" >&2
fi

echo "== kernel A/B bench → BENCH_kernels.json =="
BENCH_OUT="$(pwd)/BENCH_kernels.json" \
    cargo bench --bench bench_perf_ab --manifest-path "$manifest"

echo "== telemetry hot-path bench → BENCH_metrics.json =="
# bench_metrics exits non-zero if a counter! increment exceeds its 50ns
# gate (i.e. someone snuck a lock into the metrics hot path).
BENCH_OUT="$(pwd)/BENCH_metrics.json" \
    cargo bench --bench bench_metrics --manifest-path "$manifest"

echo "== trace hot-path bench → BENCH_trace.json =="
# bench_trace exits non-zero if a sample-miss trace_span! exceeds its
# 20ns gate (i.e. the always-on tracing fast path grew a clock read or
# a ring write).
BENCH_OUT="$(pwd)/BENCH_trace.json" \
    cargo bench --bench bench_trace --manifest-path "$manifest"

echo "== serve batching A/B bench → BENCH_serve.json =="
# bench_serve exits non-zero unless p95 queue wait improves with
# 4 shards + adaptive linger over 1 shard + fixed 8ms linger.
BENCH_OUT="$(pwd)/BENCH_serve.json" \
    cargo bench --bench bench_serve --manifest-path "$manifest"

echo "== telemetry smoke: serve demo + snapshot =="
# The demo needs AOT artifacts; skip (don't fail) when they are absent,
# matching how the artifact-gated tests behave.
if [ -d "${COGNATE_ARTIFACTS:-artifacts}" ]; then
    snap="$(pwd)/METRICS_serve_demo.json"
    trace_json="$(pwd)/TRACE_serve_demo.json"
    COGNATE_TRACE_SAMPLE=1 \
        cargo run --release --manifest-path "$manifest" --example serve_demo -- \
        --metrics-out "$snap" --trace-out "$trace_json"
    if command -v python3 >/dev/null 2>&1; then
        python3 - "$snap" <<'EOF'
import json, sys
snap = json.load(open(sys.argv[1]))
jobs = snap["counters"]["serve.jobs_total"]
qcount = snap["histograms"]["serve.queue_wait_us"]["count"]
assert jobs > 0, f"serve.jobs_total is {jobs}"
assert qcount == jobs, f"queue_wait count {qcount} != jobs_total {jobs}"
print(f"telemetry smoke OK: jobs_total={jobs}, queue_wait count matches")
EOF
    else
        # Fallback: the snapshot must at least parse-ish and report jobs.
        grep -q '"serve.jobs_total":[1-9]' "$snap" \
            || { echo "verify.sh: serve.jobs_total is zero/missing in $snap" >&2; exit 1; }
        echo "telemetry smoke OK (grep fallback)"
    fi
else
    echo "verify.sh: artifacts/ absent — skipping serve-demo telemetry smoke"
fi

echo "== trace smoke: Chrome-trace export is well-formed =="
# The demo above ran with COGNATE_TRACE_SAMPLE=1, so every served job
# must be in the export: the JSON must parse as Chrome trace_event,
# with sorted non-negative timestamps and the full serve span tree.
if [ -f "${trace_json:-}" ]; then
    if command -v python3 >/dev/null 2>&1; then
        python3 - "$trace_json" <<'EOF'
import json, sys
trace = json.load(open(sys.argv[1]))
events = trace["traceEvents"]
assert events, "trace export is empty at sampling 1.0"
last_ts = -1
for e in events:
    assert e["ph"] == "X", f"unexpected phase {e['ph']!r}"
    assert e["ts"] >= last_ts >= -1 and e["ts"] >= 0, f"ts not monotonic: {e}"
    assert e["dur"] >= 0, f"negative dur: {e}"
    last_ts = e["ts"]
names = {e["name"] for e in events}
need = {"serve.accept", "serve.queue", "serve.linger", "serve.featurize",
        "serve.score", "serve.reply"}
missing = need - names
assert not missing, f"span tree incomplete, missing {sorted(missing)}"
print(f"trace smoke OK: {len(events)} spans, monotonic ts, tree complete")
EOF
    else
        grep -q '"traceEvents"' "$trace_json" \
            && grep -q '"serve.accept"' "$trace_json" \
            && grep -q '"serve.score"' "$trace_json" \
            || { echo "verify.sh: $trace_json missing serve spans" >&2; exit 1; }
        echo "trace smoke OK (grep fallback)"
    fi
else
    echo "verify.sh: no trace export (artifacts absent) — skipping trace smoke"
fi

echo "verify.sh: all gates passed"
