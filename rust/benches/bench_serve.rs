//! Serve-path A/B: single batcher + fixed linger (the seed shape) vs
//! sharded batchers + adaptive linger, under a bursty multi-connection
//! load. The model behind each shard is synthetic (sleep-based
//! featurize/score), so the bench isolates *batching policy* — router,
//! queues, linger controller — from PJRT, needs no artifacts, and lets
//! shards genuinely overlap (the real runtime serialises executions on
//! an internal lock; the win there comes from the linger policy and
//! overlapping the non-PJRT work).
//!
//! Gate: p95 `serve.queue_wait_us` must improve with 4 shards +
//! adaptive linger vs 1 shard + fixed 8ms. With 8 connections, each
//! with one request in flight, a FEAT_B=16 batch can never fill, so
//! the fixed window makes every job eat the full 8ms linger — the
//! adaptive controller's shrink rule is exactly what removes it.
//!
//! Results land in `BENCH_serve.json` at the repo root (override with
//! `BENCH_OUT`).

use cognate::coordinator::serve::{self, LingerPolicy, ServeModel, ServeOpts};
use cognate::sparse::gen::{generate, Family};
use cognate::util::json::Json;
use cognate::util::metrics::registry;
use std::io::{BufRead, BufReader, Write};
use std::time::{Duration, Instant};

/// Featurizer batch width: above the max in-flight job count (8
/// connections × 1 outstanding each) so fixed-linger batches never
/// fill early.
const FEAT_B: usize = 16;
/// One synthetic featurize call (per batch — the amortisable cost).
const FEATURIZE_COST: Duration = Duration::from_millis(3);
/// One synthetic scoring call (per job).
const SCORE_COST: Duration = Duration::from_micros(200);
const FIXED_LINGER: Duration = Duration::from_millis(8);

const N_CONNS: usize = 8;
const BURSTS: usize = 4;
const BURST_LEN: usize = 4;
const BURST_GAP: Duration = Duration::from_millis(6);
const TOTAL_JOBS: usize = N_CONNS * BURSTS * BURST_LEN;

struct SyntheticModel;

impl ServeModel for SyntheticModel {
    fn feat_b(&self) -> usize {
        FEAT_B
    }
    fn featurize(&mut self, dmaps: &[&[f32]]) -> anyhow::Result<Vec<Vec<f32>>> {
        std::thread::sleep(FEATURIZE_COST);
        Ok(dmaps.iter().map(|_| vec![0.0; 8]).collect())
    }
    fn score(&mut self, _embed: &[f32], _cols: usize) -> anyhow::Result<Vec<f64>> {
        std::thread::sleep(SCORE_COST);
        Ok((0..64).map(|i| i as f64).collect())
    }
}

struct LoadStats {
    p50_us: f64,
    p95_us: f64,
    mean_us: f64,
    wall_ms: f64,
    batches: usize,
}

/// Drive TOTAL_JOBS bursty jobs through a service with `shards`
/// synthetic shards under `linger`, and read the queue-wait
/// distribution back out of the (reset) global registry.
fn run_load(shards: usize, linger: LingerPolicy) -> LoadStats {
    registry().reset_all();
    let models: Vec<Box<dyn ServeModel>> =
        (0..shards).map(|_| Box::new(SyntheticModel) as Box<dyn ServeModel>).collect();
    let opts = ServeOpts { shards, linger, max_jobs: Some(TOTAL_JOBS), ..ServeOpts::default() };
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let server = std::thread::spawn(move || {
        serve::serve_models(models, "127.0.0.1:0", opts, move |a| {
            let _ = addr_tx.send(a);
        })
        .expect("serve_models");
    });
    let addr = addr_rx.recv_timeout(Duration::from_secs(30)).expect("server ready");

    let t0 = Instant::now();
    let clients: Vec<_> = (0..N_CONNS)
        .map(|conn| {
            std::thread::spawn(move || {
                // One persistent connection per client, bursts of
                // request/reply cycles separated by idle gaps.
                let m = generate(Family::Banded, 100, 100, 0.05, conn as u64);
                let stream = std::net::TcpStream::connect(addr).expect("connect");
                let mut writer = stream.try_clone().expect("clone");
                let mut reader = BufReader::new(stream);
                for burst in 0..BURSTS {
                    for j in 0..BURST_LEN {
                        let id = (conn * BURSTS * BURST_LEN + burst * BURST_LEN + j) as i64;
                        writeln!(writer, "{}", serve::request_payload(id, 3, &m)).expect("send");
                        let mut reply = String::new();
                        reader.read_line(&mut reply).expect("reply");
                        let resp = Json::parse(&reply).expect("reply JSON");
                        assert!(
                            resp.get("error").is_none(),
                            "server error: {}",
                            resp.to_string()
                        );
                    }
                    if burst + 1 < BURSTS {
                        std::thread::sleep(BURST_GAP);
                    }
                }
            })
        })
        .collect();
    for c in clients {
        c.join().expect("client");
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    server.join().expect("server joins once the job budget is spent");

    let snap = registry().snapshot();
    let jobs = snap.req("counters").req("serve.jobs_total").as_usize().expect("jobs_total");
    let q = snap.req("histograms").req("serve.queue_wait_us");
    let qcount = q.req("count").as_usize().expect("count");
    assert_eq!(jobs, TOTAL_JOBS, "every job dequeued exactly once");
    assert_eq!(qcount, jobs, "queue_wait_us.count == jobs_total at quiescence");
    let batches =
        snap.req("histograms").req("serve.batch_size").req("count").as_usize().expect("batches");
    LoadStats {
        p50_us: q.req("p50").as_f64().expect("p50"),
        p95_us: q.req("p95").as_f64().expect("p95"),
        mean_us: q.req("mean").as_f64().expect("mean"),
        wall_ms,
        batches,
    }
}

fn repo_root() -> std::path::PathBuf {
    let start = std::env::current_dir().unwrap_or_else(|_| ".".into());
    let mut d = start.clone();
    loop {
        if d.join("CHANGES.md").exists() || d.join(".git").exists() {
            return d;
        }
        if !d.pop() {
            return start;
        }
    }
}

fn side_json(s: &LoadStats) -> Json {
    Json::obj(vec![
        ("queue_wait_p50_us", Json::Num(s.p50_us)),
        ("queue_wait_p95_us", Json::Num(s.p95_us)),
        ("queue_wait_mean_us", Json::Num(s.mean_us)),
        ("wall_ms", Json::Num(s.wall_ms)),
        ("batches", Json::Num(s.batches as f64)),
    ])
}

fn main() {
    println!(
        "serve A/B: {TOTAL_JOBS} jobs over {N_CONNS} connections \
         ({BURSTS} bursts × {BURST_LEN}; feat_b={FEAT_B})"
    );

    let baseline = run_load(1, LingerPolicy::Fixed(FIXED_LINGER));
    println!(
        "  1 shard, fixed {FIXED_LINGER:?}: p50={:.0}us p95={:.0}us mean={:.0}us \
         wall={:.0}ms batches={}",
        baseline.p50_us, baseline.p95_us, baseline.mean_us, baseline.wall_ms, baseline.batches
    );

    let sharded = run_load(4, LingerPolicy::adaptive_to(FIXED_LINGER));
    println!(
        "  4 shards, adaptive≤{FIXED_LINGER:?}: p50={:.0}us p95={:.0}us mean={:.0}us \
         wall={:.0}ms batches={}",
        sharded.p50_us, sharded.p95_us, sharded.mean_us, sharded.wall_ms, sharded.batches
    );

    let out_json = Json::obj(vec![
        ("baseline_1shard_fixed", side_json(&baseline)),
        ("sharded_4shard_adaptive", side_json(&sharded)),
        ("p95_improvement", Json::Num(baseline.p95_us / sharded.p95_us.max(1.0))),
        ("total_jobs", Json::Num(TOTAL_JOBS as f64)),
    ]);
    let out = std::env::var("BENCH_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| repo_root().join("BENCH_serve.json"));
    std::fs::write(&out, format!("{}\n", out_json.to_string())).expect("write bench json");
    println!("wrote {}", out.display());

    if sharded.p95_us >= baseline.p95_us {
        eprintln!(
            "FAIL: sharded+adaptive p95 queue wait {:.0}us did not improve on the \
             single-batcher fixed-linger baseline {:.0}us",
            sharded.p95_us, baseline.p95_us
        );
        std::process::exit(1);
    }
    println!(
        "PASS: p95 queue wait {:.0}us → {:.0}us ({:.1}x better)",
        baseline.p95_us,
        sharded.p95_us,
        baseline.p95_us / sharded.p95_us.max(1.0)
    );
}
