//! Substrate hot paths: executable SpMM/SDDMM kernels (GFLOP/s),
//! density-map featurization, reordering, tile-grid construction.
use cognate::kernels::{sddmm_scheduled, spmm_scheduled, SddmmSchedule, SpmmSchedule};
use cognate::platform::tiles::tile_grid;
use cognate::sparse::features::density_map;
use cognate::sparse::gen::{generate, Family};
use cognate::sparse::reorder::{apply, Reorder};
use cognate::util::bench::{bench, black_box};
use cognate::util::rng::Rng;

fn main() {
    let m = generate(Family::Rmat, 4000, 4000, 0.005, 3);
    let n = 128usize;
    let mut rng = Rng::new(1);
    let b: Vec<f32> = (0..m.cols * n).map(|_| rng.next_f32()).collect();
    let mut out = vec![0f32; m.rows * n];
    let flops = 2.0 * m.nnz() as f64 * n as f64 / 1e9;
    println!("matrix {}x{} nnz={} dense_n={n}", m.rows, m.cols, m.nnz());

    for (name, s) in [
        ("spmm/default", SpmmSchedule::default()),
        ("spmm/tuned-i16-k128", SpmmSchedule { i_block: 16, k_block: 128, outer_k: false }),
        ("spmm/outer-k", SpmmSchedule { i_block: 64, k_block: 32, outer_k: true }),
    ] {
        let r = bench(name, 1, 10, 4.0, || {
            spmm_scheduled(&m, &b, n, s, &mut out);
            black_box(&out);
        });
        println!("  -> {:.2} GFLOP/s", flops / r.mean_s);
        r.report();
    }

    let bd: Vec<f32> = (0..m.rows * n).map(|_| rng.next_f32()).collect();
    let mut dv = vec![0f32; m.nnz()];
    bench("sddmm/default", 1, 10, 4.0, || {
        sddmm_scheduled(&m, &bd, &b, n, SddmmSchedule::default(), &mut dv);
        black_box(&dv);
    })
    .report();

    bench("density_map[32x32x4]", 1, 50, 3.0, || {
        black_box(density_map(&m));
    })
    .report();
    bench("reorder/degree", 1, 20, 3.0, || {
        black_box(apply(&m, Reorder::DegreeDesc));
    })
    .report();
    bench("reorder/rcm", 1, 10, 3.0, || {
        black_box(apply(&m, Reorder::Rcm));
    })
    .report();
    bench("tile_grid[32x16384]", 1, 30, 3.0, || {
        black_box(tile_grid(&m, 32, 16384));
    })
    .report();
}
