//! Telemetry hot-path micro-bench and regression gate.
//!
//! The metrics substrate promises lock-free, allocation-free updates:
//! a counter bump through the `counter!` macro is one `OnceLock` load
//! plus one relaxed `fetch_add`. This bench measures the per-op cost of
//! every hot-path primitive and *fails* (non-zero exit) if the macro
//! counter increment exceeds `MAX_NS_PER_INC` — so a future "just wrap
//! it in a Mutex" regression breaks `scripts/verify.sh`, not production.
//!
//! Results land in `BENCH_metrics.json` at the repo root (override with
//! `BENCH_OUT`). No artifacts required.

use cognate::util::bench::{bench, black_box};
use cognate::util::json::Json;
use cognate::util::metrics::{Counter, Histogram};

/// Gate: macro-path counter increment must stay below this (the ISSUE
/// budget is 50ns; typical hardware lands in the low single digits).
const MAX_NS_PER_INC: f64 = 50.0;

/// Inner-loop size: large enough to amortize the harness's `Instant`
/// reads down to noise, small enough to keep iterations snappy.
const OPS: usize = 10_000;

fn repo_root() -> std::path::PathBuf {
    let start = std::env::current_dir().unwrap_or_else(|_| ".".into());
    let mut d = start.clone();
    loop {
        if d.join("CHANGES.md").exists() || d.join(".git").exists() {
            return d;
        }
        if !d.pop() {
            return start;
        }
    }
}

fn ns_per_op(min_s: f64) -> f64 {
    min_s * 1e9 / OPS as f64
}

fn main() {
    let mut results: Vec<(&str, f64)> = Vec::new();

    // 1. Raw cell: the floor — a single relaxed fetch_add.
    let raw = Counter::new();
    let r = bench("counter.inc (raw cell)", 5, 200, 2.0, || {
        for _ in 0..OPS {
            black_box(&raw).inc();
        }
    });
    r.report();
    results.push(("counter_inc_raw_ns", ns_per_op(r.min_s)));

    // 2. Macro path: what instrumented code actually pays (OnceLock
    //    load + fetch_add). This is the gated number.
    let r = bench("counter! macro increment", 5, 200, 2.0, || {
        for _ in 0..OPS {
            cognate::counter!("bench.metrics.ctr").inc();
        }
    });
    r.report();
    let macro_ns = ns_per_op(r.min_s);
    results.push(("counter_inc_macro_ns", macro_ns));

    // 3. Histogram observe: leading_zeros bucket + 3 fetch_adds.
    let hist = Histogram::new();
    let r = bench("histogram.observe", 5, 200, 2.0, || {
        for i in 0..OPS {
            black_box(&hist).observe(i as u64);
        }
    });
    r.report();
    results.push(("histogram_observe_ns", ns_per_op(r.min_s)));

    // 4. Gauge set through the macro.
    let r = bench("gauge! macro set", 5, 200, 2.0, || {
        for i in 0..OPS {
            cognate::gauge!("bench.metrics.g").set(i as f64);
        }
    });
    r.report();
    results.push(("gauge_set_macro_ns", ns_per_op(r.min_s)));

    // 5. time_span! around a trivial body: two Instant reads + observe.
    let r = bench("time_span! empty body", 5, 100, 2.0, || {
        for i in 0..OPS / 10 {
            black_box(cognate::time_span!("bench.metrics.span_us", i + 1));
        }
    });
    r.report();
    results.push(("time_span_ns", r.min_s * 1e9 / (OPS / 10) as f64));

    let mut obj: Vec<(&str, Json)> = results.iter().map(|&(k, v)| (k, Json::Num(v))).collect();
    obj.push(("max_ns_per_inc_gate", Json::Num(MAX_NS_PER_INC)));
    let out = std::env::var("BENCH_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| repo_root().join("BENCH_metrics.json"));
    std::fs::write(&out, format!("{}\n", Json::obj(obj).to_string())).expect("write bench json");
    println!("wrote {}", out.display());

    if macro_ns > MAX_NS_PER_INC {
        eprintln!(
            "FAIL: counter! increment {macro_ns:.1}ns/op exceeds the {MAX_NS_PER_INC:.0}ns gate \
             (did the hot path grow a lock?)"
        );
        std::process::exit(1);
    }
    println!("PASS: counter! increment {macro_ns:.1}ns/op (< {MAX_NS_PER_INC:.0}ns gate)");
}
