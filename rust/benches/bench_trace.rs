//! Tracing hot-path micro-bench and regression gate.
//!
//! The tracing substrate promises that an *unsampled* span is near
//! free: `trace_span!` on the miss path is one thread-local context
//! read, one relaxed atomic load, and a branch. This bench measures
//! that miss path and *fails* (non-zero exit) if it exceeds
//! [`GATE_NS`] — so tracing can stay always-on in serve without a
//! perf debate. The sampled path (ring write) and `record` backfill
//! are reported alongside for context, ungated.
//!
//! Results land in `BENCH_trace.json` at the repo root (override with
//! `BENCH_OUT`). No artifacts required.

use cognate::util::bench::{bench, black_box};
use cognate::util::json::Json;
use cognate::util::trace::{self, TraceCtx};

/// Gate: a sample-miss `trace_span!` must stay below this per op.
const GATE_NS: f64 = 20.0;

/// Inner-loop size: large enough to amortize the harness's `Instant`
/// reads down to noise, small enough to keep iterations snappy.
const OPS: usize = 10_000;

fn repo_root() -> std::path::PathBuf {
    let start = std::env::current_dir().unwrap_or_else(|_| ".".into());
    let mut d = start.clone();
    loop {
        if d.join("CHANGES.md").exists() || d.join(".git").exists() {
            return d;
        }
        if !d.pop() {
            return start;
        }
    }
}

fn ns_per_op(min_s: f64) -> f64 {
    min_s * 1e9 / OPS as f64
}

fn main() {
    let mut results: Vec<(&str, f64)> = Vec::new();

    // 1. Disabled (sample = 0): the always-on cost every untraced
    //    request pays. This is the gated number.
    trace::set_sample(0.0);
    let r = bench("trace_span! sample miss (p=0)", 5, 200, 2.0, || {
        for i in 0..OPS {
            black_box(cognate::trace_span!("pool.task", i + 1));
        }
    });
    r.report();
    let miss_ns = ns_per_op(r.min_s);
    results.push(("span_miss_ns", miss_ns));

    // 2. Fractional sampling: adds one thread-local SplitMix64 step on
    //    the miss path (and a ring write on the ~0.1% of hits).
    trace::set_sample(0.001);
    let r = bench("trace_span! sample miss (p=0.001)", 5, 200, 2.0, || {
        for i in 0..OPS {
            black_box(cognate::trace_span!("pool.task", i + 1));
        }
    });
    r.report();
    results.push(("span_miss_fractional_ns", ns_per_op(r.min_s)));

    // 3. Fully sampled: two clock reads plus the seqlock ring write
    //    (the rings overwrite-oldest, so lapping them here is fine).
    trace::set_sample(1.0);
    let r = bench("trace_span! sampled (p=1)", 5, 100, 2.0, || {
        for i in 0..OPS {
            black_box(cognate::trace_span!("pool.task", i + 1));
        }
    });
    r.report();
    results.push(("span_sampled_ns", ns_per_op(r.min_s)));

    // 4. record() backfill: one id draw plus the ring write, no clock.
    let ctx = TraceCtx { trace_id: 0xBE7C, span: 1 };
    let r = bench("trace::record backfill", 5, 100, 2.0, || {
        for i in 0..OPS {
            black_box(trace::record("serve.queue", ctx, i as u64, 1, &[("shard", 0)]));
        }
    });
    r.report();
    results.push(("record_ns", ns_per_op(r.min_s)));
    trace::set_sample(0.0);
    drop(trace::drain()); // leave the rings empty for whoever runs next

    let mut obj: Vec<(&str, Json)> = results.iter().map(|&(k, v)| (k, Json::Num(v))).collect();
    obj.push(("span_miss_gate_ns", Json::Num(GATE_NS)));
    let out = std::env::var("BENCH_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| repo_root().join("BENCH_trace.json"));
    std::fs::write(&out, format!("{}\n", Json::obj(obj).to_string())).expect("write bench json");
    println!("wrote {}", out.display());

    if miss_ns > GATE_NS {
        eprintln!(
            "FAIL: sample-miss trace_span! costs {miss_ns:.1}ns/op, exceeding the {GATE_NS:.0}ns \
             gate (did the miss path grow a clock read or a ring write?)"
        );
        std::process::exit(1);
    }
    println!("PASS: sample-miss trace_span! {miss_ns:.1}ns/op (< {GATE_NS:.0}ns gate)");
}
