//! Training-step latency per model variant (the pre-training /
//! fine-tuning throughput). Requires `make artifacts`.
use cognate::model::{ModelDriver, TrainBatch};
use cognate::runtime::{artifacts_dir, Runtime};
use cognate::util::bench::bench;
use cognate::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let rt = Arc::new(Runtime::load(&artifacts_dir()).expect("make artifacts first"));
    for variant in ["cognate", "noife", "waco_fm", "tf"] {
        let mut d = ModelDriver::init(rt.clone(), variant, 0).unwrap();
        let mut rng = Rng::new(7);
        let b = d.train_b();
        let mk = |n: usize, rng: &mut Rng| (0..n).map(|_| rng.next_f32()).collect::<Vec<_>>();
        let batch = TrainBatch {
            dmap: mk(b * d.dmap_len(), &mut rng),
            cfg_a: mk(b * d.cfg_dim, &mut rng),
            z_a: mk(b * d.latent_dim(), &mut rng),
            cfg_b: mk(b * d.cfg_dim, &mut rng),
            z_b: mk(b * d.latent_dim(), &mut rng),
            sign: vec![1.0; b],
            weight: vec![1.0; b],
        };
        bench(&format!("train_step/{variant}"), 2, 20, 10.0, || {
            let _ = d.train_step(&batch).unwrap();
        })
        .report_throughput(b as f64, "pair");
    }
}
