//! Perf A/B harness.
//!
//! Part 1 (always runs, no artifacts needed): seed-vs-optimized kernel
//! A/B. The A-side is a faithful copy of the seed's `spmm_parallel`
//! (even row-*count* partition, schedule dropped); the B-side is the
//! current nnz-balanced, schedule-honoring implementation. Run on a
//! degree-sorted power-law matrix — the worst case for row-count
//! splitting, since the first chunk holds most of the nonzeros. Results
//! land in `BENCH_kernels.json` at the repo root (override with
//! `BENCH_OUT`).
//!
//! Part 2 (skipped gracefully when AOT artifacts are absent): the
//! original train/featurize/score benches against the artifacts
//! directory named in COGNATE_ARTIFACTS — used to compare candidate
//! kernel schedules (e.g. COGNATE_BLOCK_M) against baseline.

use cognate::kernels::{
    sddmm_parallel, sddmm_scheduled, spmm_parallel, SddmmSchedule, SpmmSchedule, DENSE_DIM,
};
use cognate::model::{ModelDriver, TrainBatch};
use cognate::runtime::{artifacts_dir, Runtime};
use cognate::sparse::csr::Csr;
use cognate::sparse::gen::{generate, Family};
use cognate::sparse::reorder::{apply, Reorder};
use cognate::util::bench::bench;
use cognate::util::json::Json;
use cognate::util::rng::Rng;
use std::sync::Arc;

/// The seed's parallel SpMM, preserved verbatim as the A-side baseline:
/// rows split evenly by count, naive inner loop, schedule ignored.
fn seed_spmm_parallel(
    a: &Csr,
    b: &[f32],
    n: usize,
    s: SpmmSchedule,
    threads: usize,
    out: &mut [f32],
) {
    assert_eq!(out.len(), a.rows * n);
    out.fill(0.0);
    let threads = threads.max(1);
    let rows_per = a.rows.div_ceil(threads);
    let chunks: Vec<(usize, &mut [f32])> = out
        .chunks_mut(rows_per * n)
        .enumerate()
        .map(|(t, c)| (t * rows_per, c))
        .collect();
    std::thread::scope(|scope| {
        for (row0, chunk) in chunks {
            scope.spawn(move || {
                let rows = chunk.len() / n;
                for i in 0..rows {
                    let gi = row0 + i;
                    let dst = &mut chunk[i * n..(i + 1) * n];
                    for (&j, &v) in a.row_indices(gi).iter().zip(a.row_values(gi)) {
                        let brow = &b[j as usize * n..(j as usize + 1) * n];
                        for k in 0..n {
                            dst[k] += v * brow[k];
                        }
                    }
                }
                let _ = s;
            });
        }
    });
}

/// Repo root = nearest ancestor holding CHANGES.md or .git (cargo runs
/// bench binaries from the package dir, one level down).
fn repo_root() -> std::path::PathBuf {
    let start = std::env::current_dir().unwrap_or_else(|_| ".".into());
    let mut d = start.clone();
    loop {
        if d.join("CHANGES.md").exists() || d.join(".git").exists() {
            return d;
        }
        if !d.pop() {
            return start;
        }
    }
}

fn kernel_ab() -> Json {
    let threads = 8usize;
    let n = DENSE_DIM;
    // Degree-sorted power law: nnz concentrated in the leading rows, the
    // pathological case for even row-count partitioning.
    let raw = generate(Family::PowerLaw, 4096, 4096, 0.004, 7);
    let m = apply(&raw, Reorder::DegreeDesc);
    let mut rng = Rng::new(0xAB);
    let b: Vec<f32> = (0..m.cols * n).map(|_| rng.next_f32() - 0.5).collect();
    let bt: Vec<f32> = (0..m.rows * n).map(|_| rng.next_f32() - 0.5).collect();
    let c: Vec<f32> = (0..n * m.cols).map(|_| rng.next_f32() - 0.5).collect();
    let ss = SpmmSchedule::default();
    let sd = SddmmSchedule::default();

    // Correctness gate before timing: both sides accumulate j-ascending
    // per output element, so they must agree bitwise.
    let mut out_a = vec![0f32; m.rows * n];
    let mut out_b = vec![0f32; m.rows * n];
    seed_spmm_parallel(&m, &b, n, ss, threads, &mut out_a);
    spmm_parallel(&m, &b, n, ss, threads, &mut out_b);
    assert_eq!(out_a, out_b, "seed and nnz-balanced SpMM disagree");

    let r_seed = bench("spmm/seed-rowsplit/8t", 3, 40, 5.0, || {
        seed_spmm_parallel(&m, &b, n, ss, threads, &mut out_a)
    });
    r_seed.report();
    let r_new = bench("spmm/nnz-balanced/8t", 3, 40, 5.0, || {
        spmm_parallel(&m, &b, n, ss, threads, &mut out_b)
    });
    r_new.report();
    let r_one = bench("spmm/nnz-balanced/1t", 1, 20, 5.0, || {
        spmm_parallel(&m, &b, n, ss, 1, &mut out_b)
    });
    r_one.report();

    let mut vals_a = vec![0f32; m.nnz()];
    let mut vals_b = vec![0f32; m.nnz()];
    let r_sd_one = bench("sddmm/scheduled/1t", 1, 20, 5.0, || {
        sddmm_scheduled(&m, &bt, &c, n, sd, &mut vals_a)
    });
    r_sd_one.report();
    let r_sd_par = bench("sddmm/parallel/8t", 3, 40, 5.0, || {
        sddmm_parallel(&m, &bt, &c, n, sd, threads, &mut vals_b)
    });
    r_sd_par.report();
    assert_eq!(vals_a, vals_b, "parallel SDDMM disagrees with scheduled");

    let spmm_speedup = r_seed.mean_s / r_new.mean_s.max(1e-12);
    let sddmm_speedup = r_sd_one.mean_s / r_sd_par.mean_s.max(1e-12);
    println!("spmm  8t speedup vs seed rowsplit: {spmm_speedup:.2}x");
    println!("sddmm 8t speedup vs 1t:            {sddmm_speedup:.2}x");

    Json::obj(vec![
        (
            "matrix",
            Json::obj(vec![
                ("family", Json::Str("powerlaw".into())),
                ("reorder", Json::Str("degree_desc".into())),
                ("rows", Json::Num(m.rows as f64)),
                ("cols", Json::Num(m.cols as f64)),
                ("nnz", Json::Num(m.nnz() as f64)),
            ]),
        ),
        ("dense_dim", Json::Num(n as f64)),
        ("threads", Json::Num(threads as f64)),
        (
            "spmm",
            Json::obj(vec![
                ("seed_rowsplit_8t_ms", Json::Num(r_seed.mean_s * 1e3)),
                ("nnz_balanced_8t_ms", Json::Num(r_new.mean_s * 1e3)),
                ("nnz_balanced_1t_ms", Json::Num(r_one.mean_s * 1e3)),
                ("speedup_vs_seed", Json::Num(spmm_speedup)),
            ]),
        ),
        (
            "sddmm",
            Json::obj(vec![
                ("single_thread_ms", Json::Num(r_sd_one.mean_s * 1e3)),
                ("parallel_8t_ms", Json::Num(r_sd_par.mean_s * 1e3)),
                ("speedup_vs_single", Json::Num(sddmm_speedup)),
            ]),
        ),
    ])
}

fn model_benches(rt: Arc<Runtime>) {
    let mut d = ModelDriver::init(rt, "cognate", 0).unwrap();
    let mut rng = Rng::new(7);
    let b = d.train_b();
    let mk = |n: usize, rng: &mut Rng| (0..n).map(|_| rng.next_f32()).collect::<Vec<_>>();
    let batch = TrainBatch {
        dmap: mk(b * d.dmap_len(), &mut rng),
        cfg_a: mk(b * d.cfg_dim, &mut rng),
        z_a: mk(b * d.latent_dim(), &mut rng),
        cfg_b: mk(b * d.cfg_dim, &mut rng),
        z_b: mk(b * d.latent_dim(), &mut rng),
        sign: vec![1.0; b],
        weight: vec![1.0; b],
    };
    bench("train_step/cognate", 2, 15, 20.0, || {
        let _ = d.train_step(&batch).unwrap();
    })
    .report();
    let dmap: Vec<f32> = mk(d.dmap_len(), &mut rng);
    bench("featurize/batch1", 2, 15, 10.0, || {
        let _ = d.featurize(&[&dmap]).unwrap();
    })
    .report();
    let s = d.featurize(&[&dmap]).unwrap().remove(0);
    let cfgs: Vec<f32> = mk(256 * d.cfg_dim, &mut rng);
    let zs: Vec<f32> = mk(256 * d.latent_dim(), &mut rng);
    bench("score/256cfg", 2, 15, 10.0, || {
        let _ = d.score_configs(&s, &cfgs, &zs).unwrap();
    })
    .report();
}

fn main() {
    let kernels = kernel_ab();
    let out = std::env::var("BENCH_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| repo_root().join("BENCH_kernels.json"));
    let doc = Json::obj(vec![
        ("bench", Json::Str("bench_perf_ab".into())),
        ("kernels", kernels),
    ]);
    std::fs::write(&out, doc.to_string_pretty()).expect("writing BENCH_kernels.json");
    println!("wrote {out:?}");

    let dir = artifacts_dir();
    match Runtime::load(&dir) {
        Ok(rt) => {
            println!("artifacts: {dir:?}");
            model_benches(Arc::new(rt));
        }
        Err(e) => {
            println!("skipping model benches (no AOT artifacts at {dir:?}: {e})");
        }
    }
}
