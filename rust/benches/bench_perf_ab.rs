//! §Perf A/B harness: same train/featurize/score benches against the
//! artifacts directory named in COGNATE_ARTIFACTS — used to compare
//! candidate kernel schedules (e.g. COGNATE_BLOCK_M) against baseline.
use cognate::model::{ModelDriver, TrainBatch};
use cognate::runtime::{artifacts_dir, Runtime};
use cognate::util::bench::bench;
use cognate::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let dir = artifacts_dir();
    println!("artifacts: {dir:?}");
    let rt = Arc::new(Runtime::load(&dir).expect("artifacts missing"));
    let mut d = ModelDriver::init(rt.clone(), "cognate", 0).unwrap();
    let mut rng = Rng::new(7);
    let b = d.train_b();
    let mk = |n: usize, rng: &mut Rng| (0..n).map(|_| rng.next_f32()).collect::<Vec<_>>();
    let batch = TrainBatch {
        dmap: mk(b * d.dmap_len(), &mut rng),
        cfg_a: mk(b * d.cfg_dim, &mut rng),
        z_a: mk(b * d.latent_dim(), &mut rng),
        cfg_b: mk(b * d.cfg_dim, &mut rng),
        z_b: mk(b * d.latent_dim(), &mut rng),
        sign: vec![1.0; b],
        weight: vec![1.0; b],
    };
    bench("train_step/cognate", 2, 15, 20.0, || {
        let _ = d.train_step(&batch).unwrap();
    })
    .report();
    let dmap: Vec<f32> = mk(d.dmap_len(), &mut rng);
    bench("featurize/batch1", 2, 15, 10.0, || {
        let _ = d.featurize(&[&dmap]).unwrap();
    })
    .report();
    let s = d.featurize(&[&dmap]).unwrap().remove(0);
    let cfgs: Vec<f32> = mk(256 * d.cfg_dim, &mut rng);
    let zs: Vec<f32> = mk(256 * d.latent_dim(), &mut rng);
    bench("score/256cfg", 2, 15, 10.0, || {
        let _ = d.score_configs(&s, &cfgs, &zs).unwrap();
    })
    .report();
}
