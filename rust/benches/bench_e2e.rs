//! End-to-end mini-pipeline wall-clock (fig4-shaped, micro scale):
//! datasets + AE + pretrain + finetune + eval in one number. Requires
//! `make artifacts`.
use cognate::config::PlatformId;
use cognate::coordinator::{Pipeline, Scale};
use cognate::kernels::Op;
use cognate::model::ModelDriver;
use cognate::search::evaluate;
use cognate::train::{train, TrainOpts};
use std::time::Instant;

fn main() {
    let mut s = Scale::small();
    s.per_cell = 1;
    s.max_dim = 512;
    s.pretrain_matrices = 8;
    s.eval_matrices = 6;
    s.pretrain_opts = TrainOpts { epochs: 2, batches_per_epoch: 8, val_matrices: 0, ..TrainOpts::default() };
    s.finetune_opts = TrainOpts { epochs: 1, batches_per_epoch: 6, val_matrices: 0, ..TrainOpts::default() };
    s.ae_steps = 40;
    s.seed = 0xE2E;
    let t0 = Instant::now();
    let mut pipe = Pipeline::new(s).expect("make artifacts first");
    pipe.results_dir = std::env::temp_dir().join("cognate_bench_e2e");
    let op = Op::Spmm;
    let src = pipe.dataset(PlatformId::Cpu, op).unwrap();
    let tgt = pipe.dataset(PlatformId::Spade, op).unwrap();
    let t_data = t0.elapsed();
    let z_src = pipe.trained_ae(PlatformId::Cpu, "ae", 1).unwrap();
    let z_tgt = pipe.trained_ae(PlatformId::Spade, "ae", 2).unwrap();
    let t_ae = t0.elapsed();
    let (pool, _) = pipe.splits(&src);
    let idx = pipe.pretrain_subset(&src, &pool, pipe.scale.pretrain_matrices);
    let mut driver = ModelDriver::init(pipe.rt.clone(), "cognate", 0).unwrap();
    train(&mut driver, &z_src, &src, &idx, &[], &pipe.scale.pretrain_opts.clone()).unwrap();
    let t_pre = t0.elapsed();
    let (tpool, eval_idx) = pipe.splits(&tgt);
    let ft: Vec<usize> = tpool.into_iter().take(3).collect();
    let mut tuned = driver.fork_for_finetune();
    train(&mut tuned, &z_tgt, &tgt, &ft, &[], &pipe.scale.finetune_opts.clone()).unwrap();
    let t_ft = t0.elapsed();
    let di = cognate::config::default_config_index(PlatformId::Spade);
    let s5 = evaluate(&tuned, &z_tgt, &tgt, &eval_idx, di, 5).unwrap();
    let t_all = t0.elapsed();
    println!(
        "bench e2e: datasets {:.1}s | ae +{:.1}s | pretrain +{:.1}s | finetune +{:.1}s | eval +{:.1}s | total {:.1}s | top5 geomean {:.3}",
        t_data.as_secs_f64(),
        (t_ae - t_data).as_secs_f64(),
        (t_pre - t_ae).as_secs_f64(),
        (t_ft - t_pre).as_secs_f64(),
        (t_all - t_ft).as_secs_f64(),
        t_all.as_secs_f64(),
        s5.geomean_speedup
    );
}
