//! Simulator throughput: full-config-space evaluations per second per
//! platform — the quantity that replaces the paper's "two weeks per
//! SPADE sample" (Table 2's β ratios are modelled, not re-measured).
use cognate::kernels::Op;
use cognate::platform::{cpu::CpuSim, gpu::GpuSim, spade::SpadeSim, CostModel};
use cognate::sparse::gen::{generate, Family};
use cognate::util::bench::{bench, black_box};

fn main() {
    let m = generate(Family::Rmat, 2000, 2000, 0.01, 7);
    println!("matrix: {}x{} nnz={}", m.rows, m.cols, m.nnz());
    let cpu = CpuSim::new();
    let spade = SpadeSim::new();
    let gpu = GpuSim::new();
    for op in [Op::Spmm, Op::Sddmm] {
        bench(&format!("cpu.eval_all[1024cfg]/{}", op.name()), 1, 20, 5.0, || {
            black_box(cpu.eval_all(&m, op));
        })
        .report_throughput(1024.0, "cfg");
        bench(&format!("spade.eval_all[256cfg]/{}", op.name()), 1, 20, 5.0, || {
            black_box(spade.eval_all(&m, op));
        })
        .report_throughput(256.0, "cfg");
        bench(&format!("gpu.eval_all[288cfg]/{}", op.name()), 1, 20, 5.0, || {
            black_box(gpu.eval_all(&m, op));
        })
        .report_throughput(288.0, "cfg");
    }
}
