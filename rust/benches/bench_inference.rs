//! PJRT inference hot path (the search loop): featurize + score-256,
//! per batch width — the latency behind `cognate serve` and top-k
//! search. Requires `make artifacts`.
use cognate::model::ModelDriver;
use cognate::runtime::{artifacts_dir, Runtime};
use cognate::util::bench::bench;
use cognate::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let rt = Arc::new(Runtime::load(&artifacts_dir()).expect("make artifacts first"));
    let d = ModelDriver::init(rt.clone(), "cognate", 0).unwrap();
    let mut rng = Rng::new(1);
    let dmaps: Vec<Vec<f32>> =
        (0..4).map(|_| (0..d.dmap_len()).map(|_| rng.next_f32()).collect()).collect();
    let refs1: Vec<&[f32]> = dmaps[..1].iter().map(|v| v.as_slice()).collect();
    let refs4: Vec<&[f32]> = dmaps.iter().map(|v| v.as_slice()).collect();

    bench("featurize/batch1", 2, 30, 8.0, || {
        let _ = d.featurize(&refs1).unwrap();
    })
    .report();
    bench("featurize/batch4", 2, 30, 8.0, || {
        let _ = d.featurize(&refs4).unwrap();
    })
    .report_throughput(4.0, "matrix");

    let s = d.featurize(&refs1).unwrap().remove(0);
    for &n in &[64usize, 256] {
        let cfgs: Vec<f32> = (0..n * d.cfg_dim).map(|_| rng.next_f32()).collect();
        let zs: Vec<f32> = (0..n * d.latent_dim()).map(|_| rng.next_f32()).collect();
        bench(&format!("score/{n}cfg"), 2, 30, 8.0, || {
            let _ = d.score_configs(&s, &cfgs, &zs).unwrap();
        })
        .report_throughput(n as f64, "cfg");
    }
}
