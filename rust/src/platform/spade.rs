//! SPADE accelerator performance model (the paper's target platform).
//!
//! SPADE (Gerogiannis et al., ISCA'23) is a tile-based SpMM/SDDMM
//! accelerator: a control PE schedules (row-panel × column-panel) tiles
//! onto 32 processing elements, each with a software-managed local
//! buffer; tiles stream the sparse operand and gather rows/columns of
//! the dense operands; a *barrier* serialises execution into
//! column-panel phases (so a dense panel is fetched once and shared);
//! *cache bypassing* streams dense accesses straight from DRAM; *matrix
//! reordering* rebalances row panels.
//!
//! The real SPADE evaluation uses a cycle-accurate simulator that takes
//! up to two weeks per sample; this deterministic tile-level model is
//! the DESIGN.md substitution. It reproduces the first-order effects the
//! configuration knobs control:
//!
//! * tiling (row/col panels, split) trades buffer fit against partial-sum
//!   traffic and per-tile scheduling overhead — matrix-dependent through
//!   the measured per-tile `nnz`/`ucols`;
//! * barrier amortises dense-panel fetches across row panels (good when
//!   many panels share columns) at the price of phase-synchronisation
//!   stalls (bad under skew);
//! * bypass pays gather-per-nnz traffic but avoids buffer thrash — wins
//!   only at very low reuse (`ucols ≈ nnz`);
//! * reorder (degree-sorted rows) fixes load imbalance on power-law
//!   matrices, costs preprocessing, and does nothing for banded ones.

use super::tiles::{makespan, tile_grid, TileGrid};
use crate::config::space::{default_config_index, spade_space, PlatformId, SpadeConfig};
use crate::config::{Config, SPADE_COL_PANELS, SPADE_ROW_PANELS};
use crate::kernels::{Op, DENSE_DIM};
use crate::sparse::Csr;

// Architecture constants (§4.1: 32 PEs at 0.8 GHz).
pub const PES: usize = 32;
/// f32 MAC lanes per PE per cycle.
pub const SIMD: f64 = 4.0;
/// DRAM bytes per cycle (≈102 GB/s at 0.8 GHz).
pub const DRAM_BPC: f64 = 128.0;
/// Per-PE software-managed buffer (bytes).
pub const PE_BUF: f64 = 192.0 * 1024.0;
/// Shared on-chip cache reachable by all PEs (bytes).
pub const LLC: f64 = 8.0 * 1024.0 * 1024.0;
/// Control-PE scheduling cost per non-empty tile (cycles).
pub const TILE_OVERHEAD: f64 = 60.0;
/// Reordering preprocessing cost per nnz (cycles, parallelised over PEs).
pub const REORDER_CPN: f64 = 1.0;

/// Per-sample collection cost (Appendix A.3 sets β_SPADE = 1000).
pub const BETA: f64 = 1000.0;

pub struct SpadeSim {
    space: &'static [SpadeConfig],
    default_idx: usize,
}

impl Default for SpadeSim {
    fn default() -> Self {
        Self::new()
    }
}

struct Precomp {
    /// `grids[variant][rp_idx * 4 + cp_idx]`, variant 0 = original,
    /// 1 = degree-reordered.
    grids: Vec<Vec<TileGrid>>,
    /// Column-phase distinct-column counts, same indexing as `grids`.
    phase_ucols: Vec<Vec<Vec<u32>>>,
    nnz: f64,
    rows: f64,
}

impl SpadeSim {
    pub fn new() -> Self {
        Self { space: spade_space(), default_idx: default_config_index(PlatformId::Spade) }
    }

    pub fn num_configs(&self) -> usize {
        self.space.len()
    }

    pub fn config(&self, idx: usize) -> Config {
        Config::Spade(self.space[idx])
    }

    pub fn default_index(&self) -> usize {
        self.default_idx
    }

    fn precompute(&self, m: &Csr) -> Precomp {
        let reordered = m.permute_rows(&balanced_permutation(m));
        let mut grids = Vec::with_capacity(2);
        let mut phase_ucols = Vec::with_capacity(2);
        for mat in [m, &reordered] {
            let mut gs = Vec::with_capacity(16);
            let mut ps = Vec::with_capacity(16);
            for &rp in &SPADE_ROW_PANELS {
                for &cp in &SPADE_COL_PANELS {
                    let cp_resolved = if cp == 0 { mat.cols.max(1) } else { cp };
                    let g = tile_grid(mat, rp, cp_resolved);
                    ps.push(g.col_phase_ucols(mat));
                    gs.push(g);
                }
            }
            grids.push(gs);
            phase_ucols.push(ps);
        }
        Precomp { grids, phase_ucols, nnz: m.nnz() as f64, rows: m.rows as f64 }
    }

    /// Evaluate the cost (cycles) of every config in the space for one
    /// matrix. Shared precomputation makes this far cheaper than 256
    /// independent evaluations.
    pub fn eval_all(&self, m: &Csr, op: Op) -> Vec<f64> {
        let pre = self.precompute(m);
        self.space.iter().map(|c| cost_one(c, &pre, op)).collect()
    }
}

/// SPADE's matrix reordering: sort rows by length (descending), split
/// into `PES` degree quantiles, and interleave one row from each
/// quantile cyclically. *Every contiguous window* of the result then
/// mixes the full degree spectrum, so row panels of any size have
/// near-equal nnz — heavy rows can no longer pile into one panel and
/// bottleneck the tile scheduler (contiguous degree sort would do
/// exactly that).
pub fn balanced_permutation(m: &Csr) -> Vec<usize> {
    if m.rows == 0 {
        return Vec::new();
    }
    let mut by_len: Vec<usize> = (0..m.rows).collect();
    by_len.sort_by_key(|&r| std::cmp::Reverse(m.row_len(r)));
    let chunk = m.rows.div_ceil(PES);
    let mut perm = Vec::with_capacity(m.rows);
    for k in 0..chunk {
        for b in 0..PES {
            let idx = b * chunk + k;
            if idx < m.rows {
                perm.push(by_len[idx]);
            }
        }
    }
    perm
}

fn grid_index(c: &SpadeConfig) -> usize {
    let rp_idx = SPADE_ROW_PANELS.iter().position(|&r| r == c.row_panels).unwrap();
    let cp_idx = SPADE_COL_PANELS.iter().position(|&p| p == c.col_panels).unwrap();
    rp_idx * SPADE_COL_PANELS.len() + cp_idx
}

fn cost_one(c: &SpadeConfig, pre: &Precomp, op: Op) -> f64 {
    let variant = c.reorder as usize;
    let g = &pre.grids[variant][grid_index(c)];
    let phases = &pre.phase_ucols[variant][grid_index(c)];
    let dense = DENSE_DIM as f64;
    let w = (c.split as f64).min(dense);
    let passes = (dense / w).ceil();

    let ncp = g.n_col_panels;
    let mut bytes = 0f64;
    let mut panel_compute = vec![0f64; g.n_row_panels];
    let mut phase_tile_costs: Vec<Vec<f64>> = if c.barrier {
        vec![Vec::new(); ncp]
    } else {
        Vec::new()
    };
    let mut nonempty_tiles = 0f64;

    for p in 0..g.n_row_panels {
        for t in 0..ncp {
            let ti = g.tile(p, t);
            if ti.nnz == 0 {
                continue;
            }
            nonempty_tiles += 1.0;
            let nnz_t = ti.nnz as f64;
            let ucols_t = ti.ucols as f64;
            // Compute: one MAC per nnz per dense lane. Mixed-length rows
            // inside a panel bubble the PE's row pipeline — degree
            // reordering exists to flatten this CV.
            let bubble = 1.0 + 0.15 * g.panel_rowlen_cv[p].min(4.0);
            let comp = nnz_t * w / SIMD * bubble;
            panel_compute[p] += comp;
            if c.barrier {
                phase_tile_costs[t].push(comp);
            }
            // Dense gather traffic for this tile (per pass).
            if c.bypass {
                // Straight from DRAM, no reuse, but no fill/thrash cost.
                bytes += nnz_t * w * 4.0;
            } else if !c.barrier {
                // Panel-major: each tile fills its PE buffer from DRAM.
                let ws = ucols_t * w * 4.0;
                let thrash = (ws / PE_BUF - 1.0).clamp(0.0, 3.0);
                bytes += ws * (1.0 + thrash);
            }
            // (barrier && !bypass): dense fetch accounted per phase below.
        }
    }

    if c.barrier && !c.bypass {
        // Column-phase-major: the dense panel is fetched into the shared
        // LLC once per phase and reused by every row panel.
        for &u in phases {
            let ws = u as f64 * w * 4.0;
            let thrash = (ws / LLC - 1.0).clamp(0.0, 3.0);
            bytes += ws * (1.0 + thrash);
        }
    }

    // Sparse operand stream + output traffic.
    match op {
        Op::Spmm => {
            bytes += pre.nnz * 8.0; // A: 4B value + 4B index
            if c.barrier {
                // Partial D rows spill to DRAM between phases.
                let spills = (ncp as f64 - 1.0).max(0.0);
                bytes += pre.rows * w * 4.0 * (1.0 + 2.0 * spills);
            } else {
                // D panel resident in the PE buffer across column tiles —
                // if it fits; otherwise it spills exactly like barrier.
                let d_ws = g.row_panel as f64 * w * 4.0;
                if d_ws <= PE_BUF {
                    bytes += pre.rows * w * 4.0;
                } else {
                    let spills = (ncp as f64 - 1.0).max(0.0);
                    bytes += pre.rows * w * 4.0 * (1.0 + 2.0 * spills);
                }
            }
        }
        Op::Sddmm => {
            bytes += pre.nnz * 8.0; // A pattern + values
            // B (row operand) streams once per row panel per pass.
            bytes += pre.rows * w * 4.0;
            // D: nnz outputs; K-splitting makes partial sums per nnz.
            bytes += pre.nnz * 4.0 * (2.0 * passes - 1.0);
        }
    }
    bytes *= passes;

    // Compute makespan across PEs.
    let compute_cycles = if c.barrier {
        // Phases run back-to-back; each waits for its slowest PE.
        phase_tile_costs
            .iter()
            .map(|tc| makespan(tc, PES).0)
            .sum::<f64>()
    } else {
        makespan(&panel_compute, PES).0
    } * passes;

    let mem_cycles = bytes / DRAM_BPC;
    let sched = TILE_OVERHEAD * nonempty_tiles * passes / PES as f64;
    // Non-bypass tiles pay a small buffer-fill issue cost per distinct
    // column (lets bypass win at reuse ≈ 1).
    let fill = if c.bypass {
        0.0
    } else {
        g.tiles.iter().map(|t| t.ucols as f64).sum::<f64>() * 1.5 * passes / PES as f64
    };
    let reorder_cost = if c.reorder { pre.nnz * REORDER_CPN / PES as f64 } else { 0.0 };

    compute_cycles.max(mem_cycles) + sched + fill + reorder_cost + 2_000.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{generate, Family};
    use crate::util::stats;

    fn eval(m: &Csr, op: Op) -> Vec<f64> {
        SpadeSim::new().eval_all(m, op)
    }

    #[test]
    fn costs_positive_finite_deterministic() {
        let m = generate(Family::Rmat, 600, 600, 0.02, 1);
        let sim = SpadeSim::new();
        let a = sim.eval_all(&m, Op::Spmm);
        let b = sim.eval_all(&m, Op::Spmm);
        assert_eq!(a.len(), 256);
        assert_eq!(a, b);
        for &c in &a {
            assert!(c.is_finite() && c > 0.0);
        }
    }

    #[test]
    fn landscape_is_nontrivial() {
        // Optimal config should beat the worst config by a real factor
        // and the default by something — otherwise there is nothing for
        // a cost model to learn.
        let m = generate(Family::PowerLaw, 1500, 1500, 0.01, 2);
        let costs = eval(&m, Op::Spmm);
        let best = stats::min(&costs);
        let worst = stats::max(&costs);
        let default = costs[SpadeSim::new().default_index()];
        assert!(worst / best > 1.5, "flat landscape: {}", worst / best);
        assert!(default / best > 1.01, "default already optimal");
    }

    #[test]
    fn reorder_helps_clustered_skew_not_banded() {
        // Reordering pays off when heavy rows CLUSTER (RMAT concentrates
        // nnz at low row ids, so contiguous panels are pathologically
        // imbalanced); a banded matrix gains nothing and pays the
        // preprocessing. A uniformly-random row order is already
        // balanced — faithful to the real accelerator's behaviour.
        let sim = SpadeSim::new();
        let skewed = generate(Family::Rmat, 2000, 2000, 0.01, 3);
        let banded = generate(Family::Banded, 2000, 2000, 0.005, 3);
        for (m, expect_help) in [(&skewed, true), (&banded, false)] {
            let costs = sim.eval_all(m, Op::Spmm);
            // Compare best cost with reorder on vs off.
            let space = spade_space();
            let best_on = costs
                .iter()
                .zip(space)
                .filter(|(_, c)| c.reorder)
                .map(|(&x, _)| x)
                .fold(f64::INFINITY, f64::min);
            let best_off = costs
                .iter()
                .zip(space)
                .filter(|(_, c)| !c.reorder)
                .map(|(&x, _)| x)
                .fold(f64::INFINITY, f64::min);
            if expect_help {
                assert!(best_on < best_off, "reorder should help powerlaw");
            } else {
                assert!(best_off <= best_on, "reorder should not help banded");
            }
        }
    }

    #[test]
    fn different_matrices_have_different_optima() {
        let sim = SpadeSim::new();
        let mats = [
            generate(Family::PowerLaw, 1200, 1200, 0.015, 4),
            generate(Family::Banded, 1200, 1200, 0.005, 4),
            generate(Family::Uniform, 400, 3000, 0.02, 4),
        ];
        let mut optima = std::collections::HashSet::new();
        for m in &mats {
            let costs = sim.eval_all(m, Op::Spmm);
            let argmin = costs
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            optima.insert(argmin);
        }
        assert!(optima.len() >= 2, "all matrices share one optimum: {optima:?}");
    }

    #[test]
    fn sddmm_also_nontrivial() {
        let m = generate(Family::Rmat, 800, 800, 0.02, 5);
        let costs = eval(&m, Op::Sddmm);
        assert_eq!(costs.len(), 256);
        let spread = stats::max(&costs) / stats::min(&costs);
        assert!(spread > 1.3, "spread {spread}");
    }

    #[test]
    fn more_nnz_costs_more() {
        let sim = SpadeSim::new();
        let small = generate(Family::Uniform, 500, 500, 0.005, 6);
        let big = generate(Family::Uniform, 500, 500, 0.05, 6);
        let cs = sim.eval_all(&small, Op::Spmm);
        let cb = sim.eval_all(&big, Op::Spmm);
        let di = sim.default_index();
        assert!(cb[di] > cs[di]);
    }
}
