//! TPU roofline / VMEM-footprint estimator for the L1 Pallas kernels.
//!
//! Pallas runs `interpret=True` on the CPU plugin, so real-TPU
//! performance cannot be measured here; DESIGN.md commits to
//! *estimating* MXU utilisation and VMEM pressure from the BlockSpec
//! parameters instead. This module is that estimator: given the tile
//! shapes the AOT kernels use, it reports footprint, arithmetic
//! intensity and the roofline-limited utilisation a TPU-v4-class core
//! would see — numbers quoted in EXPERIMENTS.md §Perf.

/// TPU-v4-ish core parameters.
pub const VMEM_BYTES: f64 = 16.0 * 1024.0 * 1024.0;
pub const MXU_FLOPS_PER_S: f64 = 137.5e12; // bf16 peak per core pair
pub const HBM_BYTES_PER_S: f64 = 1.2e12;
/// MXU systolic tile.
pub const MXU_DIM: usize = 128;

#[derive(Clone, Copy, Debug)]
pub struct MatmulTile {
    pub block_m: usize,
    pub block_n: usize,
    /// Full reduction depth held in VMEM (our kernels keep K un-tiled).
    pub k: usize,
    /// Bytes per element (4 = f32; 2 = bf16 on real MXU inputs).
    pub elem_bytes: usize,
}

#[derive(Clone, Copy, Debug)]
pub struct RooflineEstimate {
    /// LHS + RHS + acc + bias tile bytes resident in VMEM.
    pub vmem_bytes: f64,
    pub vmem_fraction: f64,
    /// FLOPs per byte of HBM traffic for one output tile.
    pub arithmetic_intensity: f64,
    /// min(1, AI / ridge) — fraction of MXU peak the schedule can reach.
    pub mxu_utilization: f64,
    /// How well the tile shape fills the 128×128 systolic array.
    pub mxu_fill: f64,
}

pub fn estimate_matmul(t: &MatmulTile) -> RooflineEstimate {
    let eb = t.elem_bytes as f64;
    let (m, n, k) = (t.block_m as f64, t.block_n as f64, t.k as f64);
    let vmem = (m * k + k * n + m * n) * eb + n * eb; // + bias row
    // One output tile: read its operand panels once, write once.
    let bytes = (m * k + k * n + m * n) * eb;
    let flops = 2.0 * m * n * k;
    let ai = flops / bytes;
    let ridge = MXU_FLOPS_PER_S / HBM_BYTES_PER_S;
    let util = (ai / ridge).min(1.0);
    // Systolic fill: partial tiles waste lanes.
    let fill_m = (t.block_m as f64 / MXU_DIM as f64).min(1.0)
        * (MXU_DIM as f64 / (t.block_m as f64 / (t.block_m as f64 / MXU_DIM as f64).ceil())).min(1.0);
    let fill_n = (t.block_n.min(MXU_DIM) as f64) / MXU_DIM as f64;
    RooflineEstimate {
        vmem_bytes: vmem,
        vmem_fraction: vmem / VMEM_BYTES,
        arithmetic_intensity: ai,
        mxu_utilization: util,
        mxu_fill: fill_m.min(1.0) * fill_n,
    }
}

/// The tiles the shipped kernels actually use, per model stage
/// (mirrors python/compile: conv im2col rows = B·H·W, K = Cin·k²).
pub fn model_tiles(block_m: usize, block_n: usize) -> Vec<(&'static str, MatmulTile)> {
    vec![
        (
            "featurizer conv1 (im2col 5×5×4→8)",
            MatmulTile { block_m, block_n: block_n.min(8), k: 100, elem_bytes: 4 },
        ),
        (
            "featurizer conv deep (3×3×64→64)",
            MatmulTile { block_m, block_n: block_n.min(64), k: 576, elem_bytes: 4 },
        ),
        (
            "predictor layer 1 (256→128)",
            MatmulTile { block_m: 64, block_n: block_n.min(128), k: 256, elem_bytes: 4 },
        ),
        (
            "config mapper (53→64)",
            MatmulTile { block_m: 64, block_n: block_n.min(64), k: 53, elem_bytes: 4 },
        ),
    ]
}

/// Render the §Perf table body.
pub fn report(block_m: usize, block_n: usize) -> crate::util::table::Table {
    let mut t = crate::util::table::Table::new(
        &format!("TPU roofline estimates (BLOCK_M={block_m}, BLOCK_N={block_n})"),
        &["stage", "vmem_KiB", "vmem_frac", "flops_per_byte", "mxu_util", "mxu_fill"],
    );
    for (name, tile) in model_tiles(block_m, block_n) {
        let e = estimate_matmul(&tile);
        t.row(vec![
            name.into(),
            format!("{:.0}", e.vmem_bytes / 1024.0),
            format!("{:.4}", e.vmem_fraction),
            format!("{:.1}", e.arithmetic_intensity),
            format!("{:.2}", e.mxu_utilization),
            format!("{:.2}", e.mxu_fill),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mxu_square_tile_fits_vmem_comfortably() {
        let e = estimate_matmul(&MatmulTile { block_m: 128, block_n: 128, k: 1152, elem_bytes: 4 });
        assert!(e.vmem_fraction < 0.1, "vmem {:.3}", e.vmem_fraction);
        // AI of a square 128 tile with K=1152: 2·128²·1152 / (3.1e5·4B) ≈ 30.
        assert!(e.arithmetic_intensity > 25.0, "ai {:.1}", e.arithmetic_intensity);
    }

    #[test]
    fn widening_m_raises_intensity_until_ridge() {
        let a = estimate_matmul(&MatmulTile { block_m: 128, block_n: 128, k: 256, elem_bytes: 4 });
        let b = estimate_matmul(&MatmulTile { block_m: 1024, block_n: 128, k: 256, elem_bytes: 4 });
        assert!(b.arithmetic_intensity > a.arithmetic_intensity);
        assert!(b.vmem_bytes > a.vmem_bytes);
        assert!(b.vmem_fraction < 1.0, "1024-row tile must still fit VMEM");
    }

    #[test]
    fn tiny_n_wastes_the_array() {
        let e = estimate_matmul(&MatmulTile { block_m: 128, block_n: 8, k: 100, elem_bytes: 4 });
        assert!(e.mxu_fill < 0.1, "8-wide output cannot fill a 128-wide MXU");
    }

    #[test]
    fn report_renders_all_stages() {
        let t = report(1024, 128);
        assert_eq!(t.rows.len(), 4);
        assert!(t.render().contains("predictor"));
    }

    #[test]
    fn bf16_halves_footprint() {
        let f32t = estimate_matmul(&MatmulTile { block_m: 128, block_n: 128, k: 512, elem_bytes: 4 });
        let bf16 = estimate_matmul(&MatmulTile { block_m: 128, block_n: 128, k: 512, elem_bytes: 2 });
        assert!((bf16.vmem_bytes - f32t.vmem_bytes / 2.0).abs() < 1.0);
    }
}
