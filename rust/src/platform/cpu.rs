//! CPU (TACO / Xeon-class) analytical cost model — the *source* platform.
//!
//! Models a 32-thread server CPU running TACO-generated SpMM/SDDMM loop
//! nests under the CPU config space: strip-mining (I, J, K), loop
//! reordering, and format reordering. First-order effects:
//!
//! * the (i_split × j_split) tile's distinct-column working set vs the
//!   per-core cache decides dense-operand traffic (measured per tile on
//!   the actual — possibly reordered — CSR structure);
//! * loop order decides whether the dense panel (J-outer orders) or the
//!   output rows (I-outer orders) stay resident, and whether the sparse
//!   operand is re-streamed per dense strip (K-outer orders);
//! * format reordering changes the per-tile working sets (computed on
//!   the permuted matrix) and pays a preprocessing cost;
//! * parallelism is over the outermost blocked loop with an LPT
//!   makespan, so skew hurts orders that parallelise rows.
//!
//! Cheap samples from this model (β = 1) pre-train the cost model that
//! is then few-shot fine-tuned on SPADE/GPU — the paper's pipeline.

use super::tiles::{makespan, tile_grid, TileGrid};
use crate::config::space::{
    cpu_space, default_config_index, CpuConfig, CpuOrder, PlatformId, CPU_I_SPLITS, CPU_J_SPLITS,
};
use crate::config::Config;
use crate::kernels::{Op, DENSE_DIM};
use crate::sparse::reorder::{apply, Reorder, ALL_REORDERS};
use crate::sparse::Csr;

/// Threads (cores) used by TACO's parallel schedule.
pub const THREADS: usize = 32;
/// f32 FMA lanes per core per cycle (AVX-512).
pub const SIMD: f64 = 16.0;
/// DRAM bytes per cycle across the socket (≈100 GB/s at 2.6 GHz).
pub const DRAM_BPC: f64 = 40.0;
/// Per-core effective cache for dense-operand reuse (L2).
pub const L2: f64 = 256.0 * 1024.0;
/// Shared LLC slice per core under full occupancy.
pub const LLC_PER_CORE: f64 = 512.0 * 1024.0;
/// Loop-nest bookkeeping cost per tile iteration (cycles).
pub const TILE_ITER_OVERHEAD: f64 = 8.0;
/// Format-reordering preprocessing cost per nnz (cycles, parallel).
pub const REORDER_CPN: f64 = 4.0;

/// β_CPU = 1 (Appendix A.3): CPU samples are the cheap ones.
pub const BETA: f64 = 1.0;

pub struct CpuSim {
    space: &'static [CpuConfig],
    default_idx: usize,
}

impl Default for CpuSim {
    fn default() -> Self {
        Self::new()
    }
}

struct Precomp {
    /// `grids[variant][i_idx * 4 + j_idx]` — variant indexes ALL_REORDERS.
    grids: Vec<Vec<TileGrid>>,
    nnz: f64,
    rows: f64,
    /// Distinct columns used anywhere in the matrix (variant-invariant:
    /// row permutations never change the column set).
    u_global: f64,
}

impl CpuSim {
    pub fn new() -> Self {
        Self { space: cpu_space(), default_idx: default_config_index(PlatformId::Cpu) }
    }

    pub fn num_configs(&self) -> usize {
        self.space.len()
    }

    pub fn config(&self, idx: usize) -> Config {
        Config::Cpu(self.space[idx])
    }

    pub fn default_index(&self) -> usize {
        self.default_idx
    }

    fn precompute(&self, m: &Csr) -> Precomp {
        let mut grids = Vec::with_capacity(ALL_REORDERS.len());
        for &strategy in &ALL_REORDERS {
            let mat = apply(m, strategy);
            let mut gs = Vec::with_capacity(16);
            for &ib in &CPU_I_SPLITS {
                for &jb in &CPU_J_SPLITS {
                    // j_split strips the reduction (columns of A); the
                    // column-panel width is j_split columns.
                    gs.push(tile_grid(&mat, ib, jb));
                }
            }
            grids.push(gs);
        }
        let mut used = vec![false; m.cols];
        for &c in &m.indices {
            used[c as usize] = true;
        }
        let u_global = used.iter().filter(|&&u| u).count() as f64;
        Precomp { grids, nnz: m.nnz() as f64, rows: m.rows as f64, u_global }
    }

    pub fn eval_all(&self, m: &Csr, op: Op) -> Vec<f64> {
        let pre = self.precompute(m);
        self.space.iter().map(|c| cost_one(c, &pre, op)).collect()
    }
}

fn grid_index(c: &CpuConfig) -> usize {
    let i = CPU_I_SPLITS.iter().position(|&x| x == c.i_split).unwrap();
    let j = CPU_J_SPLITS.iter().position(|&x| x == c.j_split).unwrap();
    i * CPU_J_SPLITS.len() + j
}

/// Order classification driving the reuse regime.
#[derive(PartialEq)]
enum Regime {
    /// j1 outermost: dense panel stationary, output revisited per panel.
    JOuter,
    /// k1 outermost: sparse operand re-streamed per dense strip.
    KOuter,
    /// i1 outermost: row-blocked, output stationary.
    IOuter,
}

fn regime(o: CpuOrder) -> Regime {
    match o {
        CpuOrder::JOuter | CpuOrder::BStationary => Regime::JOuter,
        CpuOrder::KOuter | CpuOrder::KJOuter => Regime::KOuter,
        _ => Regime::IOuter,
    }
}

fn cost_one(c: &CpuConfig, pre: &Precomp, op: Op) -> f64 {
    let g = &pre.grids[c.format.index()][grid_index(c)];
    let dense = DENSE_DIM as f64;
    let kw = (c.k_split as f64).min(dense);
    let reg = regime(c.order);
    // K-outer orders make a full pass over the sparse structure per
    // dense strip; others touch it once (dense strips live in registers).
    let sparse_passes = if reg == Regime::KOuter { (dense / kw).ceil() } else { 1.0 };

    let mut bytes = 0f64;
    let mut block_cost = vec![0f64; g.n_row_panels];
    let mut tile_iters = 0f64;

    // Effective cache for dense reuse: K-outer strips shrink the live
    // dense slice so the same ucols fit better.
    let cache = L2 + LLC_PER_CORE;
    let dense_w = if reg == Regime::KOuter { kw } else { dense };

    for p in 0..g.n_row_panels {
        for t in 0..g.n_col_panels {
            let ti = g.tile(p, t);
            if ti.nnz == 0 {
                continue;
            }
            tile_iters += 1.0;
            let nnz_t = ti.nnz as f64;
            let ucols_t = ti.ucols as f64;
            // Gather latency: the probability a dense-row access misses
            // the live working set rises smoothly with the tile's
            // distinct-column footprint (soft cache capacity). This is
            // what separates banded (tiny ucols — prefetch-friendly)
            // from uniform scatter at equal nnz.
            let p_miss = 1.0 - (-(ucols_t * dense_w * 4.0) / cache).exp();
            block_cost[p] += nnz_t * dense / SIMD + nnz_t * p_miss * 12.0;
            match reg {
                Regime::JOuter => {
                    // Dense panel resident across the row sweep: fetched
                    // once per column panel (accounted below), but the
                    // output row block is re-touched per panel.
                }
                _ => {
                    // Refetch traffic beyond the cold fetch (added once
                    // below): global cache pressure makes cross-tile
                    // reuse fail, tile overflow makes intra-tile reuse
                    // fail. K-outer strips shrink both working sets.
                    let ws_tile = ucols_t * dense_w * 4.0;
                    let pressure =
                        (pre.u_global * dense_w * 4.0 / cache - 1.0).clamp(0.0, 1.0);
                    let overflow = (ws_tile / cache - 1.0).clamp(0.0, 2.0);
                    bytes += ucols_t * dense * 4.0 * (pressure + overflow);
                }
            }
        }
    }

    if reg == Regime::JOuter {
        // Dense panel fetched once per column panel — IF the panel fits
        // in cache. An oversized panel is refetched by every row block.
        let phase = g.col_phase_ucols_approx();
        for &u in &phase {
            let ws = u as f64 * dense * 4.0;
            let refetch = if ws <= cache {
                1.0
            } else {
                1.0 + (ws / cache - 1.0).min(1.0) * (g.n_row_panels as f64 - 1.0)
            };
            bytes += u as f64 * dense * 4.0 * refetch;
        }
        // ...but the output is read+written once per column panel.
        let out_rows = match op {
            Op::Spmm => pre.rows * dense * 4.0,
            Op::Sddmm => pre.nnz * 4.0,
        };
        bytes += out_rows * (2.0 * g.n_col_panels as f64 - 1.0);
    } else {
        let out_rows = match op {
            Op::Spmm => pre.rows * dense * 4.0,
            Op::Sddmm => pre.nnz * 4.0,
        };
        // I-outer keeps the output block in cache across column panels;
        // every order pays the cold dense fetch once.
        bytes += out_rows;
    }
    bytes += pre.u_global * dense * 4.0;

    // Sparse operand stream (+ B rows for SDDMM).
    bytes += pre.nnz * 8.0 * sparse_passes;
    if op == Op::Sddmm {
        bytes += pre.rows * dense * 4.0 * sparse_passes;
    }

    // Parallelism: rows (blocks) are the parallel dimension except for
    // J-outer orders, which parallelise inside a panel and synchronise
    // per panel (worse under skew).
    let (mk, _) = makespan(&block_cost, THREADS);
    let compute = match reg {
        Regime::JOuter => {
            // Per-panel barrier: pay the panel-wise imbalance.
            mk * 1.15
        }
        _ => mk,
    } * sparse_passes;

    let mem = bytes / DRAM_BPC;
    let overhead = TILE_ITER_OVERHEAD * tile_iters * sparse_passes / THREADS as f64
        + (dense / kw) * g.n_row_panels as f64 * 2.0 / THREADS as f64;
    let reorder_cost = if c.format != Reorder::None {
        pre.nnz * REORDER_CPN / THREADS as f64
            + if c.format == Reorder::Rcm { pre.nnz * 2.0 / THREADS as f64 } else { 0.0 }
    } else {
        0.0
    };

    compute.max(mem) + overhead + reorder_cost + 5_000.0
}

impl TileGrid {
    /// Approximate distinct columns per column panel without the matrix:
    /// max over row panels (a resident panel must hold at least that).
    fn col_phase_ucols_approx(&self) -> Vec<u32> {
        let mut out = vec![0u32; self.n_col_panels];
        for p in 0..self.n_row_panels {
            for t in 0..self.n_col_panels {
                out[t] = out[t].max(self.tile(p, t).ucols);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{generate, Family};
    use crate::util::stats;

    #[test]
    fn deterministic_positive() {
        let m = generate(Family::Rmat, 500, 500, 0.02, 1);
        let sim = CpuSim::new();
        let a = sim.eval_all(&m, Op::Spmm);
        assert_eq!(a.len(), 1024);
        assert_eq!(a, sim.eval_all(&m, Op::Spmm));
        assert!(a.iter().all(|&c| c.is_finite() && c > 0.0));
    }

    #[test]
    fn landscape_nontrivial_and_matrix_dependent() {
        let sim = CpuSim::new();
        let mut optima = std::collections::HashSet::new();
        for (f, seed) in [(Family::PowerLaw, 2), (Family::Banded, 3), (Family::Uniform, 4)] {
            let m = generate(f, 1000, 1000, 0.01, seed);
            let costs = sim.eval_all(&m, Op::Spmm);
            assert!(stats::max(&costs) / stats::min(&costs) > 1.5);
            let argmin = costs
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            optima.insert(argmin);
        }
        assert!(optima.len() >= 2);
    }

    #[test]
    fn scatter_reorder_is_never_best_on_banded() {
        // Destroying a banded structure should not be the optimum.
        let m = generate(Family::Banded, 1500, 1500, 0.004, 5);
        let sim = CpuSim::new();
        let costs = sim.eval_all(&m, Op::Spmm);
        let space = cpu_space();
        let argmin = costs
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_ne!(space[argmin].format, Reorder::Scatter);
    }

    #[test]
    fn sddmm_works() {
        let m = generate(Family::PowerLaw, 600, 600, 0.02, 6);
        let costs = CpuSim::new().eval_all(&m, Op::Sddmm);
        assert!(costs.iter().all(|&c| c.is_finite() && c > 0.0));
        assert!(stats::max(&costs) / stats::min(&costs) > 1.2);
    }

    #[test]
    fn correlates_with_spade_landscape() {
        // The premise of transfer: mapped-config cost landscapes on CPU
        // and SPADE are positively correlated. Compare over SPADE configs
        // by mapping each to its nearest CPU counterpart via (I, J, K).
        use crate::config::mapping::phi_spade;
        use crate::config::space::spade_space;
        use crate::platform::spade::SpadeSim;
        let m = generate(Family::Rmat, 1200, 1200, 0.01, 7);
        let cpu = CpuSim::new();
        let spade = SpadeSim::new();
        let cpu_costs = cpu.eval_all(&m, Op::Spmm);
        let spade_costs = spade.eval_all(&m, Op::Spmm);
        let cpu_cfgs = cpu_space();
        // For each SPADE config pick the CPU config with closest mapped
        // numeric parameters and default order; correlate their costs.
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for (ci, sc) in spade_space().iter().enumerate() {
            // Semantic pairing: SPADE p_row (rows/panel) ↔ CPU i_split,
            // SPADE p_col (reduction panel) ↔ CPU j_split. (The paper's φ
            // crosses the letters — I≈p_col — which is fine for the
            // learned model; for this hand-rolled sanity check we compare
            // like with like.)
            let mapped = phi_spade(sc, m.cols);
            let nearest = cpu_cfgs
                .iter()
                .enumerate()
                .filter(|(_, c)| c.format == Reorder::None && c.order == CpuOrder::RowMajor)
                .min_by_key(|(_, c)| {
                    let di = (c.i_split as f64).log2() - (mapped.j.min(4096) as f64).log2();
                    let dj = (c.j_split as f64).log2() - (mapped.i.min(4096) as f64).log2();
                    ((di * di + dj * dj) * 1000.0) as i64
                })
                .unwrap()
                .0;
            xs.push(cpu_costs[nearest].ln());
            ys.push(spade_costs[ci].ln());
        }
        let rho = stats::spearman(&xs, &ys);
        assert!(rho > 0.1, "no cross-platform correlation: rho={rho}");
    }
}
