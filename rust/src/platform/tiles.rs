//! Tile-grid precomputation shared by the platform cost models.
//!
//! Every analytical model in this crate keys on the same two per-tile
//! quantities: `nnz` (compute) and `ucols` (distinct columns touched —
//! the dense-operand working set that determines reuse in a cache /
//! scratchpad / L2). A `TileGrid` materialises those for a (row-panel ×
//! col-panel) tiling in a single O(nnz) pass over the CSR structure.

use crate::sparse::Csr;

#[derive(Clone, Copy, Debug, Default)]
pub struct TileInfo {
    pub nnz: u32,
    /// Distinct columns touched by this tile (unioned over its rows).
    pub ucols: u32,
}

#[derive(Clone, Debug)]
pub struct TileGrid {
    /// Rows per row panel.
    pub row_panel: usize,
    /// Columns per column panel.
    pub col_panel: usize,
    pub n_row_panels: usize,
    pub n_col_panels: usize,
    /// Row-panel-major tile infos: `tiles[p * n_col_panels + t]`.
    pub tiles: Vec<TileInfo>,
    /// nnz per row panel.
    pub panel_nnz: Vec<u32>,
    /// Rows in each row panel (last may be short).
    pub panel_rows: Vec<u32>,
    /// Coefficient of variation of row lengths within each row panel —
    /// mixed-length rows stall a PE's row pipeline (SPADE reordering
    /// exists precisely to shrink this).
    pub panel_rowlen_cv: Vec<f64>,
}

impl TileGrid {
    pub fn tile(&self, panel: usize, col_tile: usize) -> TileInfo {
        self.tiles[panel * self.n_col_panels + col_tile]
    }

    /// Distinct columns across a whole column panel (union over all row
    /// panels) — the phase working set under barrier-synchronised
    /// (column-panel-major) execution.
    pub fn col_phase_ucols(&self, m: &Csr) -> Vec<u32> {
        let mut col_used = vec![false; m.cols];
        for &c in &m.indices {
            col_used[c as usize] = true;
        }
        let mut out = vec![0u32; self.n_col_panels];
        for (c, &used) in col_used.iter().enumerate() {
            if used {
                out[c / self.col_panel] += 1;
            }
        }
        out
    }
}

/// Build the grid in one pass. `row_panel`/`col_panel` are clamped to the
/// matrix dims so degenerate configs (panel larger than the matrix)
/// behave like "one panel".
pub fn tile_grid(m: &Csr, row_panel: usize, col_panel: usize) -> TileGrid {
    let rp = row_panel.clamp(1, m.rows.max(1));
    let cp = col_panel.clamp(1, m.cols.max(1));
    let n_row_panels = m.rows.div_ceil(rp).max(1);
    let n_col_panels = m.cols.div_ceil(cp).max(1);
    let mut tiles = vec![TileInfo::default(); n_row_panels * n_col_panels];
    let mut panel_nnz = vec![0u32; n_row_panels];
    let mut panel_rows = vec![0u32; n_row_panels];
    let mut panel_rowlen_cv = vec![0f64; n_row_panels];
    // Column stamp: last row panel that saw this column.
    let mut stamp = vec![u32::MAX; m.cols];
    for p in 0..n_row_panels {
        let r0 = p * rp;
        let r1 = ((p + 1) * rp).min(m.rows);
        panel_rows[p] = (r1 - r0) as u32;
        let base = p * n_col_panels;
        for r in r0..r1 {
            for &c in m.row_indices(r) {
                let t = c as usize / cp;
                let ti = &mut tiles[base + t];
                ti.nnz += 1;
                if stamp[c as usize] != p as u32 {
                    stamp[c as usize] = p as u32;
                    ti.ucols += 1;
                }
            }
        }
        panel_nnz[p] = (m.indptr[r1] - m.indptr[r0]) as u32;
        // Row-length CV within the panel.
        let nr = (r1 - r0) as f64;
        if nr > 1.0 {
            let mean = panel_nnz[p] as f64 / nr;
            if mean > 0.0 {
                let var = (r0..r1)
                    .map(|r| {
                        let l = (m.indptr[r + 1] - m.indptr[r]) as f64;
                        (l - mean) * (l - mean)
                    })
                    .sum::<f64>()
                    / nr;
                panel_rowlen_cv[p] = var.sqrt() / mean;
            }
        }
    }
    TileGrid {
        row_panel: rp,
        col_panel: cp,
        n_row_panels,
        n_col_panels,
        tiles,
        panel_nnz,
        panel_rows,
        panel_rowlen_cv,
    }
}

/// Greedy LPT makespan: assign `costs` (any order) to `workers` bins,
/// largest first, each to the currently least-loaded bin. Returns
/// (makespan, mean load). The standard 4/3-approximation — good enough
/// to model a dynamic tile scheduler.
pub fn makespan(costs: &[f64], workers: usize) -> (f64, f64) {
    let workers = workers.max(1);
    if costs.is_empty() {
        return (0.0, 0.0);
    }
    let total: f64 = costs.iter().sum();
    let mean = total / workers as f64;
    if costs.len() <= workers {
        let mx = costs.iter().cloned().fold(0.0, f64::max);
        return (mx.max(mean), mean);
    }
    let mut sorted: Vec<f64> = costs.to_vec();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    // Binary-heap-free least-loaded tracking: workers is small (≤ 128).
    let mut loads = vec![0.0f64; workers];
    for c in sorted {
        let (argmin, _) = loads
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        loads[argmin] += c;
    }
    let mk = loads.iter().cloned().fold(0.0, f64::max);
    (mk, mean)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{generate, Family};

    #[test]
    fn grid_conserves_nnz() {
        let m = generate(Family::Rmat, 300, 500, 0.02, 1);
        for &(rp, cp) in &[(4usize, 64usize), (32, 1024), (1000, 100), (7, 13)] {
            let g = tile_grid(&m, rp, cp);
            let tile_sum: u32 = g.tiles.iter().map(|t| t.nnz).sum();
            assert_eq!(tile_sum as usize, m.nnz(), "rp={rp} cp={cp}");
            let panel_sum: u32 = g.panel_nnz.iter().sum();
            assert_eq!(panel_sum as usize, m.nnz());
            let rows_sum: u32 = g.panel_rows.iter().sum();
            assert_eq!(rows_sum as usize, m.rows);
        }
    }

    #[test]
    fn ucols_bounds() {
        let m = generate(Family::PowerLaw, 256, 256, 0.03, 2);
        let g = tile_grid(&m, 32, 64);
        for t in &g.tiles {
            assert!(t.ucols <= t.nnz);
            assert!(t.ucols as usize <= 64); // within the col panel
        }
    }

    #[test]
    fn single_panel_grid_ucols_is_total_distinct() {
        let m = generate(Family::Uniform, 200, 300, 0.01, 3);
        let g = tile_grid(&m, m.rows, m.cols);
        assert_eq!(g.n_row_panels, 1);
        assert_eq!(g.n_col_panels, 1);
        let mut used = vec![false; m.cols];
        for &c in &m.indices {
            used[c as usize] = true;
        }
        let distinct = used.iter().filter(|&&u| u).count();
        assert_eq!(g.tile(0, 0).ucols as usize, distinct);
    }

    #[test]
    fn col_phase_ucols_sums_to_distinct_cols() {
        let m = generate(Family::Banded, 400, 400, 0.01, 4);
        let g = tile_grid(&m, 64, 100);
        let phases = g.col_phase_ucols(&m);
        assert_eq!(phases.len(), g.n_col_panels);
        let mut used = vec![false; m.cols];
        for &c in &m.indices {
            used[c as usize] = true;
        }
        let distinct: u32 = used.iter().filter(|&&u| u).count() as u32;
        assert_eq!(phases.iter().sum::<u32>(), distinct);
    }

    #[test]
    fn makespan_basics() {
        // One big job dominates.
        let (mk, mean) = makespan(&[10.0, 1.0, 1.0, 1.0], 4);
        assert_eq!(mk, 10.0);
        assert!((mean - 13.0 / 4.0).abs() < 1e-12);
        // Perfectly divisible.
        let (mk, _) = makespan(&[1.0; 8], 4);
        assert!((mk - 2.0).abs() < 1e-12);
        // Fewer jobs than workers.
        let (mk, _) = makespan(&[3.0, 5.0], 8);
        assert_eq!(mk, 5.0);
        // Empty.
        assert_eq!(makespan(&[], 4).0, 0.0);
    }

    #[test]
    fn makespan_never_below_mean_or_max() {
        let costs: Vec<f64> = (1..40).map(|i| (i * 7 % 13) as f64 + 0.5).collect();
        let (mk, mean) = makespan(&costs, 6);
        let mx = costs.iter().cloned().fold(0.0, f64::max);
        assert!(mk >= mean - 1e-9);
        assert!(mk >= mx - 1e-9);
    }

    #[test]
    fn degenerate_dims_clamped() {
        let m = generate(Family::Uniform, 10, 10, 0.2, 5);
        let g = tile_grid(&m, 10_000, 10_000);
        assert_eq!(g.n_row_panels, 1);
        assert_eq!(g.n_col_panels, 1);
    }
}
