//! GPU (A100 / SparseTIR) analytical cost model — the second target.
//!
//! SIMT model of SparseTIR-style SpMM/SDDMM schedules. First-order
//! effects of the config space:
//!
//! * **binding** decides the work-to-execution-unit mapping and with it
//!   the divergence/utilisation penalty under row-length skew (computed
//!   from actual per-warp row-length statistics):
//!   row-per-thread diverges on skew, row-per-warp wastes lanes on short
//!   rows, nnz-balanced is immune but pays atomics;
//! * **strip-mining** (i_split, k1, k2) sets block shapes: L2 reuse of
//!   the gathered dense operand is measured per i-block via `ucols`;
//! * **unrolling** trims loop bookkeeping but raises register pressure
//!   (occupancy penalty at high factors);
//! * **vectorize** improves achieved DRAM efficiency for the contiguous
//!   dense accesses when the inner strip is wide enough.

use super::tiles::tile_grid;
use crate::config::space::{
    default_config_index, gpu_space, GpuBinding, GpuConfig, PlatformId, GPU_I_SPLITS,
};
use crate::config::Config;
use crate::kernels::{Op, DENSE_DIM};
use crate::sparse::Csr;

/// Streaming multiprocessors.
pub const SMS: usize = 108;
/// f32 FMA lanes per SM per cycle.
pub const LANES_PER_SM: f64 = 64.0;
/// DRAM bytes per cycle (≈1.4 TB/s at 1.41 GHz).
pub const DRAM_BPC: f64 = 1000.0;
/// L2 capacity (bytes) for dense-operand reuse.
pub const L2: f64 = 40.0 * 1024.0 * 1024.0;
/// Kernel-launch fixed cost (cycles).
pub const LAUNCH: f64 = 8_000.0;
/// Per-sample collection cost: real-hardware but contended/instrumented.
pub const BETA: f64 = 50.0;

pub struct GpuSim {
    space: &'static [GpuConfig],
    default_idx: usize,
}

impl Default for GpuSim {
    fn default() -> Self {
        Self::new()
    }
}

struct Precomp {
    /// Per-warp (32 consecutive rows) mean and max row length.
    warp_mean: Vec<f64>,
    warp_max: Vec<f64>,
    /// `ucols` per i-block for each i_split choice (block = i_split rows).
    block_ucols: Vec<Vec<u32>>,
    row_lens: Vec<usize>,
    nnz: f64,
    rows: f64,
}

impl GpuSim {
    pub fn new() -> Self {
        Self { space: gpu_space(), default_idx: default_config_index(PlatformId::Gpu) }
    }

    pub fn num_configs(&self) -> usize {
        self.space.len()
    }

    pub fn config(&self, idx: usize) -> Config {
        Config::Gpu(self.space[idx])
    }

    pub fn default_index(&self) -> usize {
        self.default_idx
    }

    fn precompute(&self, m: &Csr) -> Precomp {
        let row_lens = m.row_lengths();
        let mut warp_mean = Vec::new();
        let mut warp_max = Vec::new();
        for chunk in row_lens.chunks(32) {
            let mx = *chunk.iter().max().unwrap_or(&0) as f64;
            let mean = chunk.iter().sum::<usize>() as f64 / chunk.len() as f64;
            warp_mean.push(mean);
            warp_max.push(mx);
        }
        let block_ucols = GPU_I_SPLITS
            .iter()
            .map(|&ib| {
                let g = tile_grid(m, ib, m.cols.max(1));
                (0..g.n_row_panels).map(|p| g.tile(p, 0).ucols).collect()
            })
            .collect();
        Precomp { warp_mean, warp_max, block_ucols, row_lens, nnz: m.nnz() as f64, rows: m.rows as f64 }
    }

    pub fn eval_all(&self, m: &Csr, op: Op) -> Vec<f64> {
        let pre = self.precompute(m);
        self.space.iter().map(|c| cost_one(c, &pre, op)).collect()
    }
}

fn cost_one(c: &GpuConfig, pre: &Precomp, op: Op) -> f64 {
    let dense = DENSE_DIM as f64;
    let total_lanes = SMS as f64 * LANES_PER_SM;
    let flops = pre.nnz * dense;

    // ---- execution-efficiency factor from the binding --------------------
    let eff = match c.binding {
        GpuBinding::RowPerThread => {
            // Warp takes as long as its longest row ⇒ divergence factor.
            let mut num = 0.0;
            let mut den = 0.0;
            for (mx, mean) in pre.warp_max.iter().zip(&pre.warp_mean) {
                num += mx;
                den += mean;
            }
            (den / num.max(1e-9)).clamp(0.05, 1.0)
        }
        GpuBinding::RowPerWarp => {
            // Lane utilisation = rowlen/32 capped at 1, averaged over nnz.
            let util: f64 = pre
                .row_lens
                .iter()
                .map(|&l| {
                    let l = l as f64;
                    l * (l / 32.0).min(1.0).max(1e-3) / l.max(1.0)
                })
                .sum::<f64>()
                / pre.rows.max(1.0);
            util.clamp(0.05, 1.0)
        }
        GpuBinding::RowPerBlock => {
            // Block-level balance: inherits mild divergence, amortised.
            let mut num = 0.0;
            let mut den = 0.0;
            for (mx, mean) in pre.warp_max.iter().zip(&pre.warp_mean) {
                num += mx;
                den += mean;
            }
            (den / num.max(1e-9)).sqrt().clamp(0.1, 1.0)
        }
        GpuBinding::NnzBalanced => 0.92, // near-perfect balance
    };

    // Occupancy: deep unrolling raises register pressure.
    let occupancy = match c.unroll {
        1 => 1.0,
        2 => 0.97,
        _ => 0.88,
    };
    // Loop bookkeeping saved by unrolling.
    let loop_overhead = pre.nnz * (dense / (c.k1 as f64)) * 0.5 / (c.unroll as f64);

    let compute = flops / (total_lanes * eff * occupancy) + loop_overhead / total_lanes;

    // ---- memory traffic ---------------------------------------------------
    let i_idx = GPU_I_SPLITS.iter().position(|&x| x == c.i_split).unwrap();
    let ucols = &pre.block_ucols[i_idx];
    let mut dense_bytes = 0f64;
    for &u in ucols {
        let ws = u as f64 * dense * 4.0;
        // Gathered operand reuse through L2 (shared across blocks in
        // flight — model 8 resident blocks).
        let miss = if ws * 8.0 <= L2 { 1.0 } else { 1.0 + (ws * 8.0 / L2 - 1.0).min(4.0) };
        dense_bytes += u as f64 * dense * 4.0 * miss;
    }
    let coalesce = if c.vectorize && c.k1 >= 8 { 0.75 } else { 1.0 };
    dense_bytes *= coalesce;

    let mut bytes = dense_bytes + pre.nnz * 8.0;
    match op {
        Op::Spmm => bytes += pre.rows * dense * 4.0,
        Op::Sddmm => bytes += pre.nnz * 4.0 + pre.rows * dense * 4.0,
    }
    // Atomic combine traffic for the balanced binding.
    if c.binding == GpuBinding::NnzBalanced {
        let out = match op {
            Op::Spmm => pre.rows * dense * 4.0,
            Op::Sddmm => pre.nnz * 4.0,
        };
        bytes += out * 1.5;
    }

    // Divergent warps also issue scattered, poorly-pipelined memory
    // accesses: achieved bandwidth degrades with execution efficiency.
    let mem = bytes / (DRAM_BPC * eff.sqrt());
    // Small-k2 inner strips under-fill the memory pipeline slightly.
    let k2_penalty = if c.k2 < 8 { 1.05 } else { 1.0 };

    compute.max(mem * k2_penalty) + LAUNCH
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{generate, Family};
    use crate::util::stats;

    #[test]
    fn deterministic_positive() {
        let m = generate(Family::Rmat, 700, 700, 0.02, 1);
        let sim = GpuSim::new();
        let a = sim.eval_all(&m, Op::Spmm);
        assert_eq!(a.len(), 288);
        assert_eq!(a, sim.eval_all(&m, Op::Spmm));
        assert!(a.iter().all(|&c| c.is_finite() && c > 0.0));
    }

    #[test]
    fn binding_choice_depends_on_skew() {
        let sim = GpuSim::new();
        let space = gpu_space();
        let best_binding = |m: &Csr| {
            let costs = sim.eval_all(m, Op::Spmm);
            let argmin = costs
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            space[argmin].binding
        };
        let skewed = generate(Family::PowerLaw, 3000, 3000, 0.01, 2);
        let uniform = generate(Family::Banded, 3000, 3000, 0.003, 2);
        let b_skew = best_binding(&skewed);
        let b_uni = best_binding(&uniform);
        // Skewed matrices should avoid plain row-per-thread.
        assert_ne!(b_skew, GpuBinding::RowPerThread, "skewed picked {b_skew:?}");
        // And the two inputs should not necessarily agree — at minimum
        // the landscape must have spread.
        let costs = sim.eval_all(&skewed, Op::Spmm);
        assert!(stats::max(&costs) / stats::min(&costs) > 1.3);
        let _ = b_uni;
    }

    #[test]
    fn sddmm_positive_spread() {
        let m = generate(Family::PowerLaw, 900, 900, 0.015, 3);
        let costs = GpuSim::new().eval_all(&m, Op::Sddmm);
        assert!(stats::max(&costs) / stats::min(&costs) > 1.1);
    }

    #[test]
    fn gpu_is_faster_than_cpu_overall() {
        // Sanity: the accelerator-class platform should beat the CPU
        // model on the same workload at default configs.
        use crate::platform::cpu::CpuSim;
        let m = generate(Family::Rmat, 2000, 2000, 0.01, 4);
        let g = GpuSim::new();
        let c = CpuSim::new();
        let gc = g.eval_all(&m, Op::Spmm)[g.default_index()];
        let cc = c.eval_all(&m, Op::Spmm)[c.default_index()];
        assert!(gc < cc, "gpu {gc} !< cpu {cc}");
    }
}
