//! Platform cost models: CPU (source), SPADE accelerator and GPU
//! (targets). Each exposes the same interface via [`CostModel`] so the
//! dataset collector, search, and experiments are platform-agnostic.

pub mod cpu;
pub mod gpu;
pub mod roofline;
pub mod spade;
pub mod tiles;

use crate::config::{Config, PlatformId};
use crate::kernels::Op;
use crate::sparse::Csr;

/// A platform's deterministic cost model over its config space.
pub trait CostModel: Sync + Send {
    fn id(&self) -> PlatformId;
    /// Per-sample data-collection cost β (Appendix A.3's DCE weights).
    fn beta(&self) -> f64;
    fn num_configs(&self) -> usize;
    fn config(&self, idx: usize) -> Config;
    /// Index of the programming system's default schedule (baseline).
    fn default_index(&self) -> usize;
    /// Cost (cycles) of every config for one matrix.
    fn eval_all(&self, m: &Csr, op: Op) -> Vec<f64>;
}

impl CostModel for cpu::CpuSim {
    fn id(&self) -> PlatformId {
        PlatformId::Cpu
    }
    fn beta(&self) -> f64 {
        cpu::BETA
    }
    fn num_configs(&self) -> usize {
        self.num_configs()
    }
    fn config(&self, idx: usize) -> Config {
        self.config(idx)
    }
    fn default_index(&self) -> usize {
        self.default_index()
    }
    fn eval_all(&self, m: &Csr, op: Op) -> Vec<f64> {
        self.eval_all(m, op)
    }
}

impl CostModel for spade::SpadeSim {
    fn id(&self) -> PlatformId {
        PlatformId::Spade
    }
    fn beta(&self) -> f64 {
        spade::BETA
    }
    fn num_configs(&self) -> usize {
        self.num_configs()
    }
    fn config(&self, idx: usize) -> Config {
        self.config(idx)
    }
    fn default_index(&self) -> usize {
        self.default_index()
    }
    fn eval_all(&self, m: &Csr, op: Op) -> Vec<f64> {
        self.eval_all(m, op)
    }
}

impl CostModel for gpu::GpuSim {
    fn id(&self) -> PlatformId {
        PlatformId::Gpu
    }
    fn beta(&self) -> f64 {
        gpu::BETA
    }
    fn num_configs(&self) -> usize {
        self.num_configs()
    }
    fn config(&self, idx: usize) -> Config {
        self.config(idx)
    }
    fn default_index(&self) -> usize {
        self.default_index()
    }
    fn eval_all(&self, m: &Csr, op: Op) -> Vec<f64> {
        self.eval_all(m, op)
    }
}

/// Instantiate a platform by id.
pub fn make_platform(id: PlatformId) -> Box<dyn CostModel> {
    match id {
        PlatformId::Cpu => Box::new(cpu::CpuSim::new()),
        PlatformId::Spade => Box::new(spade::SpadeSim::new()),
        PlatformId::Gpu => Box::new(gpu::GpuSim::new()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{generate, Family};

    #[test]
    fn trait_objects_work_for_all_platforms() {
        let m = generate(Family::Uniform, 300, 300, 0.02, 1);
        for id in [PlatformId::Cpu, PlatformId::Spade, PlatformId::Gpu] {
            let p = make_platform(id);
            assert_eq!(p.id(), id);
            assert!(p.beta() > 0.0);
            let costs = p.eval_all(&m, Op::Spmm);
            assert_eq!(costs.len(), p.num_configs());
            assert!(p.default_index() < p.num_configs());
            let _ = p.config(0);
        }
    }

    #[test]
    fn betas_reflect_appendix_a() {
        assert_eq!(make_platform(PlatformId::Cpu).beta(), 1.0);
        assert_eq!(make_platform(PlatformId::Spade).beta(), 1000.0);
    }
}
