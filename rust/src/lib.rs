//! # COGNATE — reproduction
//!
//! Rust + JAX + Pallas reproduction of *COGNATE: Acceleration of Sparse
//! Tensor Programs on Emerging Hardware using Transfer Learning*
//! (ICML 2025).
//!
//! COGNATE trains learned cost models for sparse tensor programs (SpMM,
//! SDDMM) on a cheap source platform (CPU) and few-shot fine-tunes them
//! for emerging accelerators (SPADE, GPU), by splitting program
//! configurations into a homogeneous component (mapped into one unified
//! strip-mining space by the φ/π functions of §3.2) and a heterogeneous
//! component (compressed into a fixed latent by per-target autoencoders,
//! §3.3).
//!
//! Architecture (see `DESIGN.md`):
//! * **L3 (this crate)** — coordinator: matrix collection, platform
//!   simulators, dataset collection, training/fine-tuning drivers,
//!   top-k search, experiments, CLI, and a batched tuning service.
//! * **L2 (`python/compile/model.py`)** — the cost model and its Adam
//!   train step in JAX, AOT-lowered to HLO text once (`make artifacts`).
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels (tiled fused
//!   matmul, conv-as-im2col, ranking loss) inside the L2 graph.
//!
//! Python never runs at request time: the `runtime` module loads the
//! HLO artifacts through the PJRT C API (`xla` crate) and the rest is
//! pure Rust.

pub mod baselines;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod kernels;
pub mod model;
pub mod platform;
pub mod runtime;
pub mod search;
pub mod sparse;
pub mod train;
pub mod util;
