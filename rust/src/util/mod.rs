//! Self-contained substrates: PRNG, JSON, statistics, thread pool,
//! tables/CSV, logging, telemetry metrics, request tracing, a bench
//! harness, and the `cognate-lint` static analysis pass. The offline
//! build has only `xla` + `anyhow` as external crates, so everything
//! else lives here.

pub mod bench;
pub mod json;
pub mod lint;
pub mod logger;
pub mod metrics;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod table;
pub mod trace;
