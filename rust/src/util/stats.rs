//! Statistics used across training, evaluation and reporting:
//! geometric mean, percentiles, ranking-quality metrics (Ordered Pair
//! Accuracy, Kendall's τ), Absolute Percentage Error, and the host-side
//! pairwise ranking loss used for validation curves (Fig 6).

/// Geometric mean of strictly positive values. Values `<= 0` are clamped
/// to a tiny epsilon so a single degenerate sample cannot poison a report.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let s: f64 = xs.iter().map(|&x| x.max(1e-12).ln()).sum();
    (s / xs.len() as f64).exp()
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// p-th percentile (0..=100) with linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Ordered Pair Accuracy: fraction of pairs (i, j) whose predicted order
/// matches the true order. Ties in the truth are skipped (paper §4.4).
pub fn ordered_pair_accuracy(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let n = pred.len();
    let mut correct = 0usize;
    let mut total = 0usize;
    for i in 0..n {
        for j in (i + 1)..n {
            let dt = truth[i] - truth[j];
            if dt == 0.0 {
                continue;
            }
            total += 1;
            let dp = pred[i] - pred[j];
            if dp * dt > 0.0 {
                correct += 1;
            }
        }
    }
    if total == 0 {
        return f64::NAN;
    }
    correct as f64 / total as f64
}

/// Kendall's τ-b (handles ties in either ranking).
pub fn kendall_tau(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let n = pred.len();
    let (mut concordant, mut discordant) = (0i64, 0i64);
    let (mut ties_p, mut ties_t) = (0i64, 0i64);
    for i in 0..n {
        for j in (i + 1)..n {
            let dp = pred[i] - pred[j];
            let dt = truth[i] - truth[j];
            if dp == 0.0 && dt == 0.0 {
                continue;
            } else if dp == 0.0 {
                ties_p += 1;
            } else if dt == 0.0 {
                ties_t += 1;
            } else if dp * dt > 0.0 {
                concordant += 1;
            } else {
                discordant += 1;
            }
        }
    }
    let n0 = concordant + discordant;
    let denom = (((n0 + ties_p) as f64) * ((n0 + ties_t) as f64)).sqrt();
    if denom == 0.0 {
        return f64::NAN;
    }
    (concordant - discordant) as f64 / denom
}

/// Pairwise margin ranking loss over all pairs (host-side mirror of the
/// L1 ranking kernel; used for validation curves where we already have
/// all scores). `truth` are runtimes: lower is better, and the model is
/// trained so that *higher score = faster config*.
pub fn pairwise_ranking_loss(pred: &[f64], truth: &[f64], margin: f64) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let n = pred.len();
    let mut loss = 0.0;
    let mut count = 0usize;
    for i in 0..n {
        for j in (i + 1)..n {
            let sign = -(truth[i] - truth[j]).signum(); // faster ⇒ higher score
            if sign == 0.0 {
                continue;
            }
            loss += (margin - sign * (pred[i] - pred[j])).max(0.0);
            count += 1;
        }
    }
    if count == 0 {
        return 0.0;
    }
    loss / count as f64
}

/// Absolute Percentage Error between the runtime of the chosen config and
/// the optimal runtime, averaged over matrices (Appendix A.2).
pub fn ape(chosen: &[f64], optimal: &[f64]) -> f64 {
    assert_eq!(chosen.len(), optimal.len());
    if chosen.is_empty() {
        return f64::NAN;
    }
    let s: f64 = chosen
        .iter()
        .zip(optimal)
        .map(|(&c, &o)| ((c - o).abs() / o.max(1e-12)) * 100.0)
        .sum();
    s / chosen.len() as f64
}

/// Pearson correlation, used to sanity-check cross-platform cost
/// landscape correlation (the premise that makes transfer possible).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if n < 2.0 {
        return f64::NAN;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    let den = (dx * dy).sqrt();
    if den == 0.0 {
        return f64::NAN;
    }
    num / den
}

/// Spearman rank correlation (Pearson over ranks, average-rank ties).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    pearson(&ranks(xs), &ranks(ys))
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert!(geomean(&[]).is_nan());
    }

    #[test]
    fn percentile_interp() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn opa_perfect_and_inverted() {
        let t = [3.0, 1.0, 2.0];
        assert_eq!(ordered_pair_accuracy(&t, &t), 1.0);
        let inv: Vec<f64> = t.iter().map(|x| -x).collect();
        assert_eq!(ordered_pair_accuracy(&inv, &t), 0.0);
    }

    #[test]
    fn ktau_matches_known() {
        // Perfect agreement = 1, perfect disagreement = -1.
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [4.0, 3.0, 2.0, 1.0];
        assert!((kendall_tau(&a, &a) - 1.0).abs() < 1e-12);
        assert!((kendall_tau(&a, &b) + 1.0).abs() < 1e-12);
        // One swap out of 6 pairs: tau = (5-1)/6.
        let c = [2.0, 1.0, 3.0, 4.0];
        assert!((kendall_tau(&c, &a) - 4.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn ranking_loss_zero_when_separated() {
        // Higher score for lower runtime, margin satisfied.
        let truth = [1.0, 2.0, 3.0];
        let pred = [30.0, 20.0, 10.0];
        assert_eq!(pairwise_ranking_loss(&pred, &truth, 1.0), 0.0);
        // Flat predictions pay exactly the margin on every pair.
        let flat = [0.0, 0.0, 0.0];
        assert!((pairwise_ranking_loss(&flat, &truth, 1.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ape_basic() {
        assert!((ape(&[1.1, 2.0], &[1.0, 2.0]) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn pearson_spearman() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y = [2.0, 4.0, 6.0, 8.0, 10.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let z = [1.0, 8.0, 27.0, 64.0, 125.0]; // monotone, nonlinear
        assert!(pearson(&x, &z) < 1.0);
        assert!((spearman(&x, &z) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ranks_handle_ties() {
        let r = ranks(&[1.0, 2.0, 2.0, 3.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }
}
