//! Minimal JSON parser and writer.
//!
//! The offline environment has no `serde`/`serde_json`, so the repo
//! carries its own small JSON implementation. It covers the full JSON
//! grammar (objects, arrays, strings with escapes, numbers, bools, null)
//! and is used for the AOT artifact manifest, dataset persistence, and
//! experiment reports. Not performance-critical: datasets use the
//! `dataset` module's compact encoding for bulk floats.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `obj["k"]` for required fields, with a useful panic message.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing required JSON key {key:?}"))
    }

    // ---- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // ---- writer ----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty-print with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; clamp — only report paths hit this.
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.src.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} found {other:?}")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                other => return Err(format!("expected , or ] found {other:?}")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .src
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            // Surrogate pairs are not needed for our manifests;
                            // map unpaired surrogates to the replacement char.
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = &self.src[self.pos..];
                    let s_rest = std::str::from_utf8(rest).map_err(|e| e.to_string())?;
                    let c = s_rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-' {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).map_err(|e| e.to_string())?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a":[1,2.5,-3e2],"b":"hi\nthere","c":true,"d":null,"e":{}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.req("a").as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.req("b").as_str(), Some("hi\nthere"));
        assert_eq!(v.req("c").as_bool(), Some(true));
        assert_eq!(v.req("d"), &Json::Null);
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Json::obj(vec![
            ("name", Json::Str("cognate".into())),
            ("dims", Json::arr_usize(&[4, 32, 32])),
        ]);
        let v2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn escapes_and_unicode() {
        let v = Json::parse(r#""tab\tquote\"uA""#).unwrap();
        assert_eq!(v.as_str(), Some("tab\tquote\"uA"));
        let s = Json::Str("a\"b\\c\nd".into()).to_string();
        assert_eq!(Json::parse(&s).unwrap().as_str(), Some("a\"b\\c\nd"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn numbers_edge() {
        assert_eq!(Json::parse("0").unwrap().as_f64(), Some(0.0));
        assert_eq!(Json::parse("-0.5e-2").unwrap().as_f64(), Some(-0.005));
        // integer-valued floats print without a decimal point
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }

    #[test]
    fn nested_deep() {
        let src = "[[[[[[1]]]]]]";
        let v = Json::parse(src).unwrap();
        assert_eq!(v.to_string(), src);
    }
}
