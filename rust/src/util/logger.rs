//! Tiny timestamped stderr logger with runtime-settable verbosity.
//! (No `log`/`env_logger` facade needed for a single binary.)

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(2); // 0=quiet 1=warn 2=info 3=debug
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

/// Seconds since first log call, for coarse progress timing.
pub fn elapsed() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(lvl: u8, tag: &str, msg: std::fmt::Arguments) {
    if lvl <= level() {
        eprintln!("[{:>9.3}s {tag}] {msg}", elapsed());
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logger::log(2, "info", format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::logger::log(1, "warn", format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logger::log(3, "debug", format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_roundtrip() {
        let old = level();
        set_level(3);
        assert_eq!(level(), 3);
        set_level(old);
    }

    #[test]
    fn elapsed_monotone() {
        let a = elapsed();
        let b = elapsed();
        assert!(b >= a);
    }
}
