//! Tiny timestamped stderr logger with runtime-settable verbosity.
//! (No `log`/`env_logger` facade needed for a single binary.)
//!
//! Each line is prefixed with elapsed milliseconds, the calling
//! thread's name (shard threads are named; unnamed threads fall back
//! to their trace ordinal `tN`), the level tag, and — when the thread
//! is inside a traced scope — the active trace id, so stderr output
//! can be correlated with exported Chrome traces:
//!
//! ```text
//! [    152.3ms shard-2 info trace=00ab54c1d2e3f401] batch of 4 scored
//! ```

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(2); // 0=quiet 1=warn 2=info 3=debug
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

/// Parse a verbosity spec: `quiet|warn|info|debug` or `0`–`3`.
pub fn parse_level(s: &str) -> Option<u8> {
    match s.trim().to_ascii_lowercase().as_str() {
        "quiet" | "0" => Some(0),
        "warn" | "1" => Some(1),
        "info" | "2" => Some(2),
        "debug" | "3" => Some(3),
        _ => None,
    }
}

/// Set the initial verbosity from the `COGNATE_LOG` env var, if set —
/// lets the serve demo and CI raise/lower log level without code
/// changes. Unrecognised values warn and leave the default in place.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("COGNATE_LOG") {
        match parse_level(&v) {
            Some(l) => set_level(l),
            None => eprintln!(
                "COGNATE_LOG={v:?} not recognised (use quiet|warn|info|debug or 0-3)"
            ),
        }
    }
}

pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

/// Seconds since first log call, for coarse progress timing.
pub fn elapsed() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Label for the calling thread: its OS name when set (serve names
/// its shard/router threads), otherwise the stable trace-thread
/// ordinal as `tN`.
fn thread_label() -> String {
    let cur = std::thread::current();
    match cur.name() {
        Some(n) if !n.is_empty() => n.to_string(),
        _ => format!("t{}", crate::util::trace::tid()),
    }
}

/// Pure formatter behind [`log`], split out so the prefix shape is
/// testable without capturing stderr. `trace_id == 0` (untraced)
/// omits the `trace=` field.
pub fn format_line(
    elapsed_ms: f64,
    thread: &str,
    tag: &str,
    trace_id: u64,
    msg: &std::fmt::Arguments,
) -> String {
    if trace_id != 0 {
        format!("[{elapsed_ms:>9.1}ms {thread} {tag} trace={trace_id:016x}] {msg}")
    } else {
        format!("[{elapsed_ms:>9.1}ms {thread} {tag}] {msg}")
    }
}

pub fn log(lvl: u8, tag: &str, msg: std::fmt::Arguments) {
    if lvl <= level() {
        let ctx = crate::util::trace::current();
        eprintln!(
            "{}",
            format_line(elapsed() * 1e3, &thread_label(), tag, ctx.trace_id, &msg)
        );
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logger::log(2, "info", format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::logger::log(1, "warn", format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logger::log(3, "debug", format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_roundtrip() {
        let old = level();
        set_level(3);
        assert_eq!(level(), 3);
        set_level(old);
    }

    #[test]
    fn parse_level_specs() {
        assert_eq!(parse_level("quiet"), Some(0));
        assert_eq!(parse_level("WARN"), Some(1));
        assert_eq!(parse_level("info"), Some(2));
        assert_eq!(parse_level("3"), Some(3));
        assert_eq!(parse_level(" debug "), Some(3));
        assert_eq!(parse_level("verbose"), None);
        assert_eq!(parse_level("7"), None);
    }

    #[test]
    fn format_line_prefix_shape() {
        let plain = format_line(152.34, "shard-2", "info", 0, &format_args!("scored 4"));
        assert!(plain.starts_with('['), "{plain}");
        assert!(plain.contains("ms shard-2 info] scored 4"), "{plain}");
        assert!(!plain.contains("trace="), "{plain}");

        let traced = format_line(7.0, "main", "warn", 0xAB54C1, &format_args!("slow"));
        assert!(traced.contains("ms main warn trace=0000000000ab54c1] slow"), "{traced}");
    }

    #[test]
    fn elapsed_monotone() {
        let a = elapsed();
        let b = elapsed();
        assert!(b >= a);
    }
}
