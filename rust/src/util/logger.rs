//! Tiny timestamped stderr logger with runtime-settable verbosity.
//! (No `log`/`env_logger` facade needed for a single binary.)

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(2); // 0=quiet 1=warn 2=info 3=debug
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

/// Parse a verbosity spec: `quiet|warn|info|debug` or `0`–`3`.
pub fn parse_level(s: &str) -> Option<u8> {
    match s.trim().to_ascii_lowercase().as_str() {
        "quiet" | "0" => Some(0),
        "warn" | "1" => Some(1),
        "info" | "2" => Some(2),
        "debug" | "3" => Some(3),
        _ => None,
    }
}

/// Set the initial verbosity from the `COGNATE_LOG` env var, if set —
/// lets the serve demo and CI raise/lower log level without code
/// changes. Unrecognised values warn and leave the default in place.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("COGNATE_LOG") {
        match parse_level(&v) {
            Some(l) => set_level(l),
            None => eprintln!(
                "COGNATE_LOG={v:?} not recognised (use quiet|warn|info|debug or 0-3)"
            ),
        }
    }
}

pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

/// Seconds since first log call, for coarse progress timing.
pub fn elapsed() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(lvl: u8, tag: &str, msg: std::fmt::Arguments) {
    if lvl <= level() {
        eprintln!("[{:>9.3}s {tag}] {msg}", elapsed());
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logger::log(2, "info", format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::logger::log(1, "warn", format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logger::log(3, "debug", format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_roundtrip() {
        let old = level();
        set_level(3);
        assert_eq!(level(), 3);
        set_level(old);
    }

    #[test]
    fn parse_level_specs() {
        assert_eq!(parse_level("quiet"), Some(0));
        assert_eq!(parse_level("WARN"), Some(1));
        assert_eq!(parse_level("info"), Some(2));
        assert_eq!(parse_level("3"), Some(3));
        assert_eq!(parse_level(" debug "), Some(3));
        assert_eq!(parse_level("verbose"), None);
        assert_eq!(parse_level("7"), None);
    }

    #[test]
    fn elapsed_monotone() {
        let a = elapsed();
        let b = elapsed();
        assert!(b >= a);
    }
}
