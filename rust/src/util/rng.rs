//! Deterministic pseudo-random number generation.
//!
//! The offline environment has no `rand` crate, so we implement
//! SplitMix64 (seeding) and Xoshiro256++ (bulk generation) — both are
//! public-domain algorithms with well-known reference behaviour. Every
//! randomized component of the pipeline (matrix generation, config
//! sampling, pair sampling, init seeds) threads one of these through so
//! runs are bit-reproducible from a single root seed.

/// SplitMix64: tiny, fast generator used to expand a `u64` seed into the
/// Xoshiro state (and usable standalone for cheap decisions).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — the workhorse PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Derive an independent child stream, e.g. one per worker thread or
    /// per matrix id, without correlated output.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`, 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)` via Lemire-style rejection (unbiased).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn next_usize(&mut self, n: usize) -> usize {
        self.next_below(n as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Bounded power-law sample with exponent `alpha > 1` over `[1, max]`
    /// (inverse-CDF). Used for skewed row degrees in graph-like matrices.
    pub fn next_powerlaw(&mut self, alpha: f64, max: f64) -> f64 {
        let u = self.next_f64();
        let a1 = 1.0 - alpha;
        // CDF of truncated Pareto on [1, max].
        ((max.powf(a1) - 1.0) * u + 1.0).powf(1.0 / a1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.next_usize(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 0 from the public-domain reference.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(sm.next_u64(), 0x6E789E6AA1B965F4);
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let mut c = Rng::new(8);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_f64_in_range_and_roughly_uniform() {
        let mut r = Rng::new(42);
        let n = 20_000;
        let mut mean = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            mean += x;
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_unbiased_small_n() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.next_below(3) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(1);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
    }

    #[test]
    fn powerlaw_bounded_and_skewed() {
        let mut r = Rng::new(5);
        let mut below2 = 0;
        for _ in 0..5_000 {
            let x = r.next_powerlaw(2.2, 1000.0);
            assert!((1.0..=1000.0).contains(&x));
            if x < 2.0 {
                below2 += 1;
            }
        }
        // Pareto(2.2): majority of mass below 2.
        assert!(below2 > 2_500, "below2={below2}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_gaussian();
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.03, "mean={m}");
        assert!((v - 1.0).abs() < 0.05, "var={v}");
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Rng::new(0);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let va: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
