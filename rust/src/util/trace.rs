//! Event-based request tracing beside the metrics registry.
//!
//! Where `util::metrics` aggregates (a histogram can say p95
//! `serve.queue_wait_us` moved, not why), this module records *spans*:
//! named, timestamped intervals linked into a per-request tree by a
//! 64-bit trace id, so one served request yields
//! `accept → parse → route → queue → linger → featurize → score → reply`
//! with shard and batch ids attached as args.
//!
//! Design constraints (mirroring the metrics substrate):
//! * **Untraced spans are near-free.** A sample-miss span costs one
//!   thread-local load plus one relaxed atomic load and a branch —
//!   `bench_trace` gates this under 20ns. Sampling is controlled by
//!   `COGNATE_TRACE_SAMPLE` (0.0–1.0; serve defaults to 0.01, CLI runs
//!   to 1.0) via [`init_from_env`].
//! * **The sampled path is allocation-free.** Completed spans are
//!   written into fixed per-thread lock-free ring buffers
//!   ([`RINGS`] rings × [`RING_CAP`] slots, every field an `AtomicU64`
//!   behind a seqlock word — no `unsafe`). Overwriting a slot that was
//!   never drained bumps `trace.dropped_total`.
//! * **Context crosses threads by value.** [`TraceCtx`] is a `Copy`
//!   pair `(trace_id, span_id)`; serve jobs carry it across the router
//!   into whichever shard dequeues them, and [`record`] backfills spans
//!   (queue wait) whose interval was timed on another thread.
//! * **Names are canonical.** Every span name must appear in
//!   [`CANON`] in `layer.name` form — enforced statically by the
//!   `cognate-lint` `trace-canon` rule; unknown names degrade to inert
//!   spans rather than corrupting the export.
//!
//! Export: [`drain`] snapshots-and-clears all rings;
//! [`to_chrome`] serializes events to Chrome `trace_event` JSON
//! (complete "X" phase events, µs timestamps) loadable in Perfetto or
//! chrome://tracing. The CLI exposes this as `--trace-out PATH`, the
//! serve protocol as a `{"trace": true}` control request, and
//! `cognate trace --addr` fetches it from a live server.
//!
//! Trace ids come from a process-global SplitMix64 stream
//! (`util::rng`) stepped with one `fetch_add` — id 0 is reserved as
//! the "untraced" sentinel everywhere.

use crate::counter;
use crate::util::json::Json;
use crate::util::rng::SplitMix64;
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

// ---- canonical span names -------------------------------------------------

/// The canonical span-name table: every `trace_span!` /
/// [`TraceSpan`] name the crate emits, in `layer.name` form. Like
/// `metrics::CANON`, this table is load-bearing: the `cognate-lint`
/// `trace-canon` rule checks every name literal against it, so adding
/// a span means adding its name here in the same PR.
pub const CANON: &[&str] = &[
    "serve.accept",
    "serve.parse",
    "serve.route",
    "serve.queue",
    "serve.linger",
    "serve.batch",
    "serve.featurize",
    "serve.score",
    "serve.reply",
    "train.step",
    "sa.chain",
    "pool.task",
];

/// Index of `name` in [`CANON`], or `None` for non-canonical names
/// (which become inert spans at runtime and lint errors statically).
pub fn canon_idx(name: &str) -> Option<u16> {
    CANON.iter().position(|n| *n == name).map(|i| i as u16)
}

/// Arg keys spans may attach (stored as 1-based indices so events stay
/// plain integers; 0 marks an empty arg slot).
pub const ARG_KEYS: &[&str] = &["shard", "batch", "jobs", "id", "chain", "step", "task"];

/// Ring buffers available process-wide; threads map onto them by
/// thread ordinal modulo [`RINGS`].
pub const RINGS: usize = 16;
/// Completed-span slots per ring (overwrite-oldest beyond this).
pub const RING_CAP: usize = 1024;
/// Arg slots per span (shard + batch covers every current producer).
pub const MAX_ARGS: usize = 2;

const GAMMA: u64 = 0x9E3779B97F4A7C15;
const NAME_INERT: u16 = u16::MAX;

// ---- trace context --------------------------------------------------------

/// Propagatable trace context: the request's trace id plus the span id
/// children should parent to. `trace_id == 0` means "not traced" and
/// makes every derived span inert.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceCtx {
    pub trace_id: u64,
    pub span: u64,
}

impl TraceCtx {
    pub const NONE: TraceCtx = TraceCtx { trace_id: 0, span: 0 };

    #[inline]
    pub fn active(&self) -> bool {
        self.trace_id != 0
    }
}

// ---- sampling + id generation ---------------------------------------------

/// Sample probability as `f64` bits; 0 (the bits of +0.0) disables
/// tracing entirely, which keeps the disabled fast path to one relaxed
/// load.
static SAMPLE_BITS: AtomicU64 = AtomicU64::new(0);

/// Set the root-span sample probability (clamped to `[0, 1]`).
pub fn set_sample(p: f64) {
    let p = if p.is_finite() { p.clamp(0.0, 1.0) } else { 0.0 };
    SAMPLE_BITS.store(p.to_bits(), Ordering::Relaxed);
}

/// Current root-span sample probability.
pub fn sample() -> f64 {
    f64::from_bits(SAMPLE_BITS.load(Ordering::Relaxed))
}

/// Initialise sampling from `COGNATE_TRACE_SAMPLE` (0.0–1.0), falling
/// back to `default_p` when unset or unparseable (serve passes 0.01,
/// CLI runs pass 1.0).
pub fn init_from_env(default_p: f64) {
    set_sample(parse_sample(
        std::env::var("COGNATE_TRACE_SAMPLE").ok().as_deref(),
        default_p,
    ));
}

/// Pure half of [`init_from_env`]: `None` and unparseable specs fall
/// back to `default_p` (with a warning for the latter).
pub fn parse_sample(spec: Option<&str>, default_p: f64) -> f64 {
    match spec {
        None => default_p,
        Some(v) => match v.trim().parse::<f64>() {
            Ok(p) => p,
            Err(_) => {
                crate::warn!("COGNATE_TRACE_SAMPLE={v:?} not a number in [0,1]; using {default_p}");
                default_p
            }
        },
    }
}

fn id_state() -> &'static AtomicU64 {
    static S: OnceLock<AtomicU64> = OnceLock::new();
    // Deterministic process seed expanded through the shared SplitMix64
    // so ids are well-mixed from the first draw.
    S.get_or_init(|| AtomicU64::new(SplitMix64::new(0xC07_9A7E).next_u64()))
}

#[inline]
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Next trace/span id: one SplitMix64 step over a shared atomic state
/// (`fetch_add` of the golden gamma, then the mix), never 0.
pub fn next_id() -> u64 {
    let s = id_state().fetch_add(GAMMA, Ordering::Relaxed).wrapping_add(GAMMA);
    let z = mix64(s);
    if z == 0 {
        1
    } else {
        z
    }
}

// ---- per-thread state -----------------------------------------------------

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static CTX: Cell<TraceCtx> = const { Cell::new(TraceCtx::NONE) };
    static TID: Cell<u64> = const { Cell::new(0) };
    static SAMPLE_RNG: Cell<u64> = const { Cell::new(0) };
}

/// Small ordinal identifying the calling thread in exported events
/// (assigned on first traced use, stable for the thread's lifetime).
pub fn tid() -> u64 {
    TID.with(|t| {
        let v = t.get();
        if v != 0 {
            v
        } else {
            let v = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            t.set(v);
            v
        }
    })
}

/// One root-span sampling decision, exposed for callers that must
/// decide before they can construct the span (the serve handler picks
/// the trace id first so client-supplied ids bypass sampling). The
/// miss path is one relaxed load plus, for 0 < p < 1, one
/// thread-local SplitMix64 step.
#[inline]
pub fn sample_hit() -> bool {
    let bits = SAMPLE_BITS.load(Ordering::Relaxed);
    if bits == 0 {
        return false;
    }
    let p = f64::from_bits(bits);
    p >= 1.0 || thread_hit(p)
}

/// Per-thread Bernoulli(p) draw via a thread-local SplitMix64 stream.
#[inline]
fn thread_hit(p: f64) -> bool {
    SAMPLE_RNG.with(|r| {
        let mut s = r.get();
        if s == 0 {
            s = next_id() | 1;
        }
        s = s.wrapping_add(GAMMA);
        r.set(s);
        let u = (mix64(s) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        u < p
    })
}

/// The calling thread's ambient trace context (set by [`enter`] /
/// `trace_span!`; `TraceCtx::NONE` outside any traced scope).
pub fn current() -> TraceCtx {
    CTX.with(Cell::get)
}

/// Restores the previous ambient context on drop.
pub struct ScopeGuard {
    prev: TraceCtx,
}

/// Make `ctx` the calling thread's ambient context until the returned
/// guard drops (used by `trace_span!` and by shard threads adopting a
/// job's carried context).
pub fn enter(ctx: TraceCtx) -> ScopeGuard {
    let prev = CTX.with(|c| {
        let p = c.get();
        c.set(ctx);
        p
    });
    ScopeGuard { prev }
}

impl Drop for ScopeGuard {
    fn drop(&mut self) {
        CTX.with(|c| c.set(self.prev));
    }
}

// ---- ring buffers ---------------------------------------------------------

/// One completed-span slot. Every field is an `AtomicU64` guarded by a
/// seqlock word (`seq`): 0 = empty, odd = write in progress, even > 0 =
/// full. All-atomic fields mean a lapped writer can at worst publish a
/// mixed event (caught by the seq re-check in [`drain`], counted in
/// `trace.dropped_total`) — never undefined behaviour.
struct Slot {
    seq: AtomicU64,
    trace_id: AtomicU64,
    span_id: AtomicU64,
    parent: AtomicU64,
    /// `name_idx | tid << 16 | a0_key << 32 | a1_key << 40` (keys are
    /// 1-based indices into [`ARG_KEYS`], 0 = unused slot).
    meta: AtomicU64,
    start_us: AtomicU64,
    dur_us: AtomicU64,
    a0: AtomicU64,
    a1: AtomicU64,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            trace_id: AtomicU64::new(0),
            span_id: AtomicU64::new(0),
            parent: AtomicU64::new(0),
            meta: AtomicU64::new(0),
            start_us: AtomicU64::new(0),
            dur_us: AtomicU64::new(0),
            a0: AtomicU64::new(0),
            a1: AtomicU64::new(0),
        }
    }
}

struct Ring {
    head: AtomicU64,
    slots: Vec<Slot>,
}

struct Tracer {
    epoch: Instant,
    rings: Vec<Ring>,
}

fn tracer() -> &'static Tracer {
    static T: OnceLock<Tracer> = OnceLock::new();
    T.get_or_init(|| Tracer {
        epoch: Instant::now(),
        rings: (0..RINGS)
            .map(|_| Ring {
                head: AtomicU64::new(0),
                slots: (0..RING_CAP).map(|_| Slot::new()).collect(),
            })
            .collect(),
    })
}

/// Microseconds since the tracer's process epoch (monotonic across
/// threads — all exported `ts` values share this clock).
pub fn now_us() -> u64 {
    tracer().epoch.elapsed().as_micros() as u64
}

type Args = [(u8, i64); MAX_ARGS];

#[allow(clippy::too_many_arguments)]
fn write_event(
    trace_id: u64,
    span_id: u64,
    parent: u64,
    name_idx: u16,
    start_us: u64,
    dur_us: u64,
    args: Args,
) {
    let t = tracer();
    let tid = tid();
    let ring = match t.rings.get(tid as usize % RINGS) {
        Some(r) => r,
        None => return,
    };
    let i = ring.head.fetch_add(1, Ordering::Relaxed);
    let slot = match ring.slots.get(i as usize % RING_CAP) {
        Some(s) => s,
        None => return,
    };
    // Claim: mark the slot mid-write. A previous undrained event (even
    // seq) or a lapped concurrent writer (odd seq) is being destroyed
    // either way — surface it as a drop.
    let prev = slot.seq.swap(2 * i + 1, Ordering::Acquire);
    if prev != 0 {
        counter!("trace.dropped_total").inc();
    }
    slot.trace_id.store(trace_id, Ordering::Relaxed);
    slot.span_id.store(span_id, Ordering::Relaxed);
    slot.parent.store(parent, Ordering::Relaxed);
    let meta = (name_idx as u64)
        | ((tid & 0xFFFF) << 16)
        | ((args[0].0 as u64) << 32)
        | ((args[1].0 as u64) << 40);
    slot.meta.store(meta, Ordering::Relaxed);
    slot.start_us.store(start_us, Ordering::Relaxed);
    slot.dur_us.store(dur_us, Ordering::Relaxed);
    slot.a0.store(args[0].1 as u64, Ordering::Relaxed);
    slot.a1.store(args[1].1 as u64, Ordering::Relaxed);
    slot.seq.store(2 * i + 2, Ordering::Release);
}

// ---- spans ----------------------------------------------------------------

/// RAII span, mirroring `metrics::Span`: records a completed event
/// into the ring on drop. Inert (sample-miss / untraced / unknown
/// name) spans skip the clock and the ring entirely.
pub struct TraceSpan {
    ctx: TraceCtx,
    parent: u64,
    name_idx: u16,
    start_us: u64,
    args: Args,
}

impl TraceSpan {
    const fn inert() -> TraceSpan {
        TraceSpan {
            ctx: TraceCtx::NONE,
            parent: 0,
            name_idx: NAME_INERT,
            start_us: 0,
            args: [(0, 0); MAX_ARGS],
        }
    }

    fn begin(name: &'static str, trace_id: u64, parent: u64) -> TraceSpan {
        let Some(idx) = canon_idx(name) else {
            return Self::inert();
        };
        TraceSpan {
            ctx: TraceCtx { trace_id, span: next_id() },
            parent,
            name_idx: idx,
            start_us: now_us(),
            args: [(0, 0); MAX_ARGS],
        }
    }

    /// Start a root span, deciding by sampling: with probability
    /// [`sample`] it opens a fresh trace, otherwise it is inert. The
    /// miss path is one relaxed load plus (for 0 < p < 1) one
    /// thread-local SplitMix64 step.
    pub fn root(name: &'static str) -> TraceSpan {
        if sample_hit() {
            Self::begin(name, next_id(), 0)
        } else {
            Self::inert()
        }
    }

    /// Root span with an explicit trace id and start timestamp. The
    /// serve handler must parse a request line before it can read the
    /// client's `"trace_id"`, so the root's interval is backdated to
    /// when the line arrived — children recorded during parsing still
    /// nest inside it. Id 0 yields an inert span.
    pub fn root_at(name: &'static str, trace_id: u64, start_us: u64) -> TraceSpan {
        if trace_id == 0 {
            return Self::inert();
        }
        let mut s = Self::begin(name, trace_id, 0);
        if s.active() {
            s.start_us = start_us;
        }
        s
    }

    /// Start a root span under a caller-supplied trace id (a client
    /// that sent `"trace_id"` asked to be traced — sampling does not
    /// apply). Id 0 falls back to sampled [`TraceSpan::root`].
    pub fn root_with_id(name: &'static str, trace_id: u64) -> TraceSpan {
        if trace_id == 0 {
            Self::root(name)
        } else {
            Self::begin(name, trace_id, 0)
        }
    }

    /// Start a child span under `parent`; inert when the parent
    /// context is untraced.
    pub fn child(name: &'static str, parent: TraceCtx) -> TraceSpan {
        if parent.trace_id == 0 {
            return Self::inert();
        }
        Self::begin(name, parent.trace_id, parent.span)
    }

    /// Context for children of this span (`NONE` when inert, so
    /// derived spans stay inert).
    pub fn ctx(&self) -> TraceCtx {
        self.ctx
    }

    pub fn active(&self) -> bool {
        self.ctx.trace_id != 0
    }

    /// Attach an arg (key must be in [`ARG_KEYS`]; at most
    /// [`MAX_ARGS`] stick, extras and unknown keys are ignored).
    pub fn set_arg(&mut self, key: &str, val: i64) {
        if !self.active() {
            return;
        }
        let Some(k) = ARG_KEYS.iter().position(|a| *a == key) else {
            return;
        };
        for slot in self.args.iter_mut() {
            if slot.0 == 0 {
                *slot = (k as u8 + 1, val);
                return;
            }
        }
    }

    /// Builder-style [`TraceSpan::set_arg`].
    pub fn arg(mut self, key: &str, val: i64) -> TraceSpan {
        self.set_arg(key, val);
        self
    }
}

impl Drop for TraceSpan {
    fn drop(&mut self) {
        if self.ctx.trace_id == 0 {
            return;
        }
        let dur = now_us().saturating_sub(self.start_us);
        write_event(
            self.ctx.trace_id,
            self.ctx.span,
            self.parent,
            self.name_idx,
            self.start_us,
            dur,
            self.args,
        );
    }
}

/// Record a span whose interval was timed externally (e.g. serve's
/// queue wait: the producer stamped `start_us`, the consuming shard
/// knows the duration). Parented to `parent.span`; returns the new
/// span's context so further children can nest under it.
pub fn record(
    name: &'static str,
    parent: TraceCtx,
    start_us: u64,
    dur_us: u64,
    args: &[(&str, i64)],
) -> TraceCtx {
    if parent.trace_id == 0 {
        return TraceCtx::NONE;
    }
    let Some(idx) = canon_idx(name) else {
        return TraceCtx::NONE;
    };
    let mut packed: Args = [(0, 0); MAX_ARGS];
    let mut n = 0;
    for (key, val) in args {
        if n >= MAX_ARGS {
            break;
        }
        if let Some(k) = ARG_KEYS.iter().position(|a| a == key) {
            packed[n] = (k as u8 + 1, *val);
            n += 1;
        }
    }
    let span = next_id();
    write_event(parent.trace_id, span, parent.span, idx, start_us, dur_us, packed);
    TraceCtx { trace_id: parent.trace_id, span }
}

// ---- drain + export -------------------------------------------------------

/// A completed span copied out of the rings.
#[derive(Clone, Copy, Debug)]
pub struct SpanEvent {
    pub trace_id: u64,
    pub span_id: u64,
    pub parent: u64,
    pub name: &'static str,
    pub tid: u16,
    pub start_us: u64,
    pub dur_us: u64,
    /// `(1-based ARG_KEYS index, value)`; key 0 = empty slot.
    pub args: Args,
}

impl SpanEvent {
    /// Value of the named arg, if attached.
    pub fn arg(&self, key: &str) -> Option<i64> {
        self.args
            .iter()
            .filter(|(k, _)| *k != 0)
            .find(|(k, _)| ARG_KEYS.get(*k as usize - 1) == Some(&key))
            .map(|&(_, v)| v)
    }

    /// Attached args as `(name, value)` pairs.
    pub fn named_args(&self) -> impl Iterator<Item = (&'static str, i64)> + '_ {
        self.args.iter().filter_map(|&(k, v)| {
            if k == 0 {
                None
            } else {
                ARG_KEYS.get(k as usize - 1).map(|name| (*name, v))
            }
        })
    }
}

/// Snapshot-and-clear every ring, returning completed spans sorted by
/// start time. Best-effort under concurrent writers: slots mid-write
/// or torn (seq changed during the copy) are skipped — they are
/// counted by the writer as drops when overwritten.
pub fn drain() -> Vec<SpanEvent> {
    let t = tracer();
    let mut out = Vec::new();
    for ring in &t.rings {
        for slot in &ring.slots {
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 || s1 % 2 == 1 {
                continue;
            }
            let trace_id = slot.trace_id.load(Ordering::Relaxed);
            let span_id = slot.span_id.load(Ordering::Relaxed);
            let parent = slot.parent.load(Ordering::Relaxed);
            let meta = slot.meta.load(Ordering::Relaxed);
            let start_us = slot.start_us.load(Ordering::Relaxed);
            let dur_us = slot.dur_us.load(Ordering::Relaxed);
            let a0 = slot.a0.load(Ordering::Relaxed) as i64;
            let a1 = slot.a1.load(Ordering::Relaxed) as i64;
            let s2 = slot.seq.load(Ordering::Acquire);
            if s2 != s1 {
                continue;
            }
            slot.seq.store(0, Ordering::Release);
            let name_idx = (meta & 0xFFFF) as u16;
            let Some(name) = CANON.get(name_idx as usize).copied() else {
                continue;
            };
            out.push(SpanEvent {
                trace_id,
                span_id,
                parent,
                name,
                tid: ((meta >> 16) & 0xFFFF) as u16,
                start_us,
                dur_us,
                args: [(((meta >> 32) & 0xFF) as u8, a0), (((meta >> 40) & 0xFF) as u8, a1)],
            });
        }
    }
    out.sort_by_key(|e| (e.start_us, e.span_id));
    out
}

/// Serialize events as Chrome `trace_event` JSON (the
/// `{"traceEvents": [...]}` object form; complete "X" events with µs
/// `ts`/`dur`), loadable in Perfetto / chrome://tracing. Trace, span,
/// and parent ids ride in each event's `args` as hex strings.
pub fn to_chrome(events: &[SpanEvent]) -> Json {
    let list = events
        .iter()
        .map(|e| {
            let mut args = vec![
                ("trace_id", Json::Str(format!("{:016x}", e.trace_id))),
                ("span_id", Json::Str(format!("{:016x}", e.span_id))),
                ("parent", Json::Str(format!("{:016x}", e.parent))),
            ];
            for (k, v) in e.named_args() {
                args.push((k, Json::Num(v as f64)));
            }
            Json::obj(vec![
                ("name", Json::Str(e.name.to_string())),
                ("ph", Json::Str("X".to_string())),
                ("ts", Json::Num(e.start_us as f64)),
                ("dur", Json::Num(e.dur_us as f64)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(e.tid as f64)),
                ("args", Json::obj(args)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::Arr(list)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

/// Drain the rings and write Chrome-trace JSON to `path` (the
/// `--trace-out` implementation shared by every CLI command).
pub fn write_chrome_trace(path: &str) -> std::io::Result<usize> {
    let events = drain();
    std::fs::write(path, to_chrome(&events).to_string_pretty())?;
    Ok(events.len())
}

// ---- macro ----------------------------------------------------------------

/// Trace a block as a span: child of the ambient thread context when
/// one is active, otherwise a sampled root. The block runs with the
/// span as the ambient context, so nested `trace_span!` calls link
/// into a tree. Returns the block's value.
///
/// `trace_span!("sa.chain", { run_chain() })`
#[macro_export]
macro_rules! trace_span {
    ($name:expr, $body:expr) => {{
        let __cur = $crate::util::trace::current();
        let __span = if __cur.trace_id != 0 {
            $crate::util::trace::TraceSpan::child($name, __cur)
        } else {
            $crate::util::trace::TraceSpan::root($name)
        };
        let __guard = $crate::util::trace::enter(__span.ctx());
        let __out = $body;
        drop(__guard);
        drop(__span);
        __out
    }};
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    // The rings, sampling knob, and ambient context are process-global;
    // tests that drain or set sampling serialize on this.
    static SERIAL: Mutex<()> = Mutex::new(());

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        SERIAL.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    #[test]
    fn canon_names_are_unique_and_layer_shaped() {
        let mut seen = std::collections::BTreeSet::new();
        for name in CANON {
            assert!(seen.insert(*name), "duplicate trace CANON entry {name}");
            assert!(
                name.split('.').count() >= 2
                    && name.split('.').all(|s| {
                        !s.is_empty()
                            && s.chars().all(|c| {
                                c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'
                            })
                    }),
                "trace CANON entry {name} is not layer.name shaped"
            );
            assert!(canon_idx(name).is_some());
        }
        assert_eq!(canon_idx("serve.accept"), Some(0));
        assert_eq!(canon_idx("no.such.span"), None);
        assert!(CANON.len() < NAME_INERT as usize);
    }

    #[test]
    fn ids_are_nonzero_and_distinct() {
        let a = next_id();
        let b = next_id();
        assert_ne!(a, 0);
        assert_ne!(b, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn forced_root_builds_a_drainable_tree() {
        let _g = lock();
        drain(); // clear residue from other tests
        let tid = 0xABCD_u64;
        {
            let root = TraceSpan::root_with_id("serve.accept", tid);
            assert!(root.active());
            {
                let child = TraceSpan::child("serve.parse", root.ctx()).arg("shard", 3);
                let _grand = TraceSpan::child("serve.score", child.ctx());
            }
            let _sibling = TraceSpan::child("serve.reply", root.ctx());
        }
        let events: Vec<SpanEvent> =
            drain().into_iter().filter(|e| e.trace_id == tid).collect();
        assert_eq!(events.len(), 4, "root + parse + score + reply");
        let root = events.iter().find(|e| e.name == "serve.accept").unwrap();
        let parse = events.iter().find(|e| e.name == "serve.parse").unwrap();
        let score = events.iter().find(|e| e.name == "serve.score").unwrap();
        let reply = events.iter().find(|e| e.name == "serve.reply").unwrap();
        assert_eq!(root.parent, 0);
        assert_eq!(parse.parent, root.span_id);
        assert_eq!(score.parent, parse.span_id);
        assert_eq!(reply.parent, root.span_id);
        assert_eq!(parse.arg("shard"), Some(3));
        assert_eq!(parse.arg("batch"), None);
        // Children drop before the root, so their intervals nest.
        assert!(parse.start_us >= root.start_us);
        assert!(parse.start_us + parse.dur_us <= root.start_us + root.dur_us);
    }

    #[test]
    fn untraced_and_unknown_spans_are_inert() {
        let _g = lock();
        drain();
        {
            let none = TraceSpan::child("serve.parse", TraceCtx::NONE);
            assert!(!none.active());
            assert_eq!(none.ctx(), TraceCtx::NONE);
            let unknown = TraceSpan::root_with_id("not.canonical", 7);
            assert!(!unknown.active());
        }
        let old = sample();
        set_sample(0.0);
        {
            let miss = TraceSpan::root("serve.accept");
            assert!(!miss.active());
        }
        set_sample(old);
        assert!(drain().iter().all(|e| e.trace_id != 7));
    }

    #[test]
    fn sampling_rate_zero_one_and_clamp() {
        let _g = lock();
        let old = sample();
        set_sample(0.5);
        assert_eq!(sample(), 0.5);
        set_sample(7.0);
        assert_eq!(sample(), 1.0);
        set_sample(-1.0);
        assert_eq!(sample(), 0.0);
        set_sample(f64::NAN);
        assert_eq!(sample(), 0.0);
        set_sample(1.0);
        let span = TraceSpan::root("serve.accept");
        assert!(span.active(), "p=1.0 always samples");
        drop(span);
        set_sample(old);
        drain();
    }

    #[test]
    fn record_backfills_external_interval() {
        let _g = lock();
        drain();
        let parent = TraceCtx { trace_id: 0x5151, span: 9 };
        let ctx = record("serve.queue", parent, 100, 50, &[("shard", 2), ("batch", 4)]);
        assert_eq!(ctx.trace_id, 0x5151);
        assert_ne!(ctx.span, 0);
        let events: Vec<SpanEvent> =
            drain().into_iter().filter(|e| e.trace_id == 0x5151).collect();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].name, "serve.queue");
        assert_eq!(events[0].parent, 9);
        assert_eq!(events[0].start_us, 100);
        assert_eq!(events[0].dur_us, 50);
        assert_eq!(events[0].arg("shard"), Some(2));
        assert_eq!(events[0].arg("batch"), Some(4));
        assert_eq!(record("serve.queue", TraceCtx::NONE, 0, 0, &[]), TraceCtx::NONE);
    }

    #[test]
    fn overwrite_oldest_bumps_dropped_total() {
        let _g = lock();
        drain();
        let dropped = crate::counter!("trace.dropped_total");
        let before = dropped.get();
        // One thread maps to one ring: > RING_CAP events must lap it.
        for _ in 0..(RING_CAP + 64) {
            let _ = record(
                "pool.task",
                TraceCtx { trace_id: 0xD20, span: 1 },
                0,
                1,
                &[],
            );
        }
        assert!(dropped.get() > before, "lapping the ring must count drops");
        let kept = drain().into_iter().filter(|e| e.trace_id == 0xD20).count();
        assert!(kept <= RING_CAP);
        assert!(kept > 0);
    }

    #[test]
    fn ambient_context_nests_via_macro() {
        let _g = lock();
        drain();
        let old = sample();
        set_sample(1.0);
        assert_eq!(current(), TraceCtx::NONE);
        let inner_ctx = crate::trace_span!("train.step", {
            let cur = current();
            assert!(cur.active(), "macro sets ambient context");
            crate::trace_span!("pool.task", {
                assert_eq!(current().trace_id, cur.trace_id);
            });
            cur
        });
        assert_eq!(current(), TraceCtx::NONE, "guard restores on exit");
        set_sample(old);
        let events: Vec<SpanEvent> =
            drain().into_iter().filter(|e| e.trace_id == inner_ctx.trace_id).collect();
        assert_eq!(events.len(), 2);
        let step = events.iter().find(|e| e.name == "train.step").unwrap();
        let task = events.iter().find(|e| e.name == "pool.task").unwrap();
        assert_eq!(task.parent, step.span_id);
    }

    #[test]
    fn chrome_export_shape_and_monotone_ts() {
        let _g = lock();
        drain();
        let tid = 0xC42_u64;
        {
            let root = TraceSpan::root_with_id("serve.accept", tid);
            let _q = record(
                "serve.queue",
                root.ctx(),
                now_us(),
                0,
                &[("shard", 1), ("batch", 2)],
            );
        }
        let events: Vec<SpanEvent> =
            drain().into_iter().filter(|e| e.trace_id == tid).collect();
        assert_eq!(events.len(), 2);
        for w in events.windows(2) {
            assert!(w[0].start_us <= w[1].start_us, "drain sorts by ts");
        }
        let json = to_chrome(&events);
        let parsed = Json::parse(&json.to_string()).expect("export must re-parse");
        let list = parsed.req("traceEvents").as_arr().expect("traceEvents array");
        assert_eq!(list.len(), 2);
        for ev in list {
            assert_eq!(ev.req("ph").as_str(), Some("X"));
            assert!(ev.req("ts").as_f64().is_some());
            assert!(ev.req("dur").as_f64().is_some());
            let args = ev.req("args");
            assert_eq!(
                args.req("trace_id").as_str(),
                Some(format!("{tid:016x}").as_str())
            );
        }
        let queue = list
            .iter()
            .find(|e| e.req("name").as_str() == Some("serve.queue"))
            .expect("queue event exported");
        assert_eq!(queue.req("args").req("shard").as_f64(), Some(1.0));
        assert_eq!(queue.req("args").req("batch").as_f64(), Some(2.0));
    }

    #[test]
    fn sample_spec_parses_and_falls_back() {
        assert_eq!(parse_sample(None, 0.25), 0.25);
        assert_eq!(parse_sample(Some("0.5"), 0.01), 0.5);
        assert_eq!(parse_sample(Some(" 1 "), 0.01), 1.0);
        assert_eq!(parse_sample(Some("nope"), 0.75), 0.75);
    }
}
