//! Lossy-but-honest Rust tokenizer for `cognate-lint`.
//!
//! This is not a parser: it splits source text into just enough token
//! classes for the rule passes — identifiers, string literals, single
//! punctuation characters, numbers, and comments (retained, because the
//! `safety-comment` rule and `lint:allow` suppressions live in them).
//! The one hard requirement is that nothing inside a string, char
//! literal, or comment ever leaks out as an identifier or punctuation
//! token: a rule must never fire on `"counter!(…)"` quoted in a test
//! fixture or a doc comment. Lifetimes (`'a`) are deliberately lexed as
//! a bare identifier (the quote is dropped); no rule keys on them.

/// Token classes. `Str` carries the literal's raw content (escapes are
/// not resolved — metric names and the patterns the rules match are
/// plain ASCII without escapes).
#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    /// Identifier or keyword.
    Ident(String),
    /// String literal content (cooked, raw, or byte form), quotes and
    /// hashes stripped.
    Str(String),
    /// Numeric literal (value unused by any rule).
    Num,
    /// Single punctuation character.
    Punct(char),
    /// `//…` or `/*…*/` comment, full text including the delimiters.
    Comment(String),
}

#[derive(Clone, Debug, PartialEq)]
pub struct Token {
    pub kind: Tok,
    /// 1-based line of the token's first character.
    pub line: u32,
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_continue(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Tokenize `src`. Never fails: unterminated constructs consume to EOF.
pub fn tokenize(src: &str) -> Vec<Token> {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
        } else if c.is_ascii_whitespace() {
            i += 1;
        } else if c == b'/' && b.get(i + 1) == Some(&b'/') {
            let start = i;
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            toks.push(Token {
                kind: Tok::Comment(String::from_utf8_lossy(&b[start..i]).into_owned()),
                line,
            });
        } else if c == b'/' && b.get(i + 1) == Some(&b'*') {
            let start = i;
            let start_line = line;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            toks.push(Token {
                kind: Tok::Comment(String::from_utf8_lossy(&b[start..i]).into_owned()),
                line: start_line,
            });
        } else if c == b'"' {
            let (s, ni, nl) = cooked_string(b, i + 1, line);
            toks.push(Token { kind: Tok::Str(s), line });
            i = ni;
            line = nl;
        } else if let Some((prefix_len, hashes)) = raw_string_prefix(b, i) {
            let start_line = line;
            let (s, ni, nl) = raw_string(b, i + prefix_len, hashes, line);
            toks.push(Token { kind: Tok::Str(s), line: start_line });
            i = ni;
            line = nl;
        } else if c == b'b' && b.get(i + 1) == Some(&b'"') {
            let (s, ni, nl) = cooked_string(b, i + 2, line);
            toks.push(Token { kind: Tok::Str(s), line });
            i = ni;
            line = nl;
        } else if c == b'\'' {
            i = char_or_lifetime(b, i, &mut toks, line);
        } else if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_continue(b[i]) {
                i += 1;
            }
            toks.push(Token {
                kind: Tok::Ident(String::from_utf8_lossy(&b[start..i]).into_owned()),
                line,
            });
        } else if c.is_ascii_digit() {
            // Numbers: digits, alnum suffixes/exponents, `_`, and `.`
            // only when a digit follows (so `0..n` stays three tokens).
            i += 1;
            while i < n {
                if is_ident_continue(b[i]) {
                    i += 1;
                } else if b[i] == b'.'
                    && b.get(i + 1).is_some_and(|d| d.is_ascii_digit())
                {
                    i += 2;
                } else {
                    break;
                }
            }
            toks.push(Token { kind: Tok::Num, line });
        } else if c.is_ascii() {
            toks.push(Token { kind: Tok::Punct(c as char), line });
            i += 1;
        } else {
            // Stray non-ASCII outside strings/comments: skip the code
            // point without splitting it.
            let ch = src[i..].chars().next().unwrap_or('\u{FFFD}');
            i += ch.len_utf8();
        }
    }
    toks
}

/// Cooked string body starting just past the opening quote. Returns
/// (content, index-past-closing-quote, line-after).
fn cooked_string(b: &[u8], mut i: usize, mut line: u32) -> (String, usize, u32) {
    let start = i;
    while i < b.len() {
        match b[i] {
            b'"' => {
                let s = String::from_utf8_lossy(&b[start..i]).into_owned();
                return (s, i + 1, line);
            }
            b'\\' => i += 2,
            b'\n' => {
                line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    (String::from_utf8_lossy(&b[start..]).into_owned(), b.len(), line)
}

/// If `b[i..]` opens a raw (or raw-byte) string, returns
/// (prefix length up to and including the opening quote, hash count).
fn raw_string_prefix(b: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if b.get(j) == Some(&b'b') {
        j += 1;
    }
    if b.get(j) != Some(&b'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while b.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) == Some(&b'"') {
        Some((j + 1 - i, hashes))
    } else {
        None
    }
}

/// Raw string body starting just past the opening quote; terminates at
/// `"` followed by `hashes` `#`s.
fn raw_string(b: &[u8], mut i: usize, hashes: usize, mut line: u32) -> (String, usize, u32) {
    let start = i;
    while i < b.len() {
        if b[i] == b'\n' {
            line += 1;
            i += 1;
        } else if b[i] == b'"' && b[i + 1..].iter().take(hashes).filter(|&&h| h == b'#').count() == hashes
        {
            let s = String::from_utf8_lossy(&b[start..i]).into_owned();
            return (s, i + 1 + hashes, line);
        } else {
            i += 1;
        }
    }
    (String::from_utf8_lossy(&b[start..]).into_owned(), b.len(), line)
}

/// Disambiguate `'x'` / `'\n'` / `'💡'` (char literals, consumed whole)
/// from `'a` lifetimes (quote dropped; the name lexes as an identifier).
/// Returns the index to continue from.
fn char_or_lifetime(b: &[u8], i: usize, toks: &mut Vec<Token>, line: u32) -> usize {
    let n = b.len();
    match b.get(i + 1) {
        None => i + 1,
        Some(&b'\\') => {
            // Escaped char literal: skip the escape, scan to the quote.
            let mut j = i + 3;
            while j < n && b[j] != b'\'' {
                j += 1;
            }
            toks.push(Token { kind: Tok::Str(String::new()), line });
            (j + 1).min(n)
        }
        Some(&next) => {
            // One UTF-8 code point then a closing quote ⇒ char literal.
            let cp_len = if next < 0x80 {
                1
            } else if next >= 0xF0 {
                4
            } else if next >= 0xE0 {
                3
            } else {
                2
            };
            if b.get(i + 1 + cp_len) == Some(&b'\'') {
                toks.push(Token { kind: Tok::Str(String::new()), line });
                i + 2 + cp_len
            } else {
                // Lifetime: drop the quote, let the name lex normally.
                i + 1
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        tokenize(src)
            .into_iter()
            .filter_map(|t| match t.kind {
                Tok::Ident(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    fn strs(src: &str) -> Vec<String> {
        tokenize(src)
            .into_iter()
            .filter_map(|t| match t.kind {
                Tok::Str(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn idents_puncts_and_lines() {
        let toks = tokenize("fn f() {\n  x.y\n}");
        assert_eq!(toks[0], Token { kind: Tok::Ident("fn".into()), line: 1 });
        let dot = toks.iter().find(|t| t.kind == Tok::Punct('.')).unwrap();
        assert_eq!(dot.line, 2);
        assert_eq!(idents("fn f() { x.y }"), vec!["fn", "f", "x", "y"]);
    }

    #[test]
    fn strings_do_not_leak_tokens() {
        // The quoted macro call must come out as ONE Str token.
        let src = r##"let s = "counter!(\"x.y\")"; g(s);"##;
        let toks = tokenize(src);
        assert!(toks.iter().all(|t| t.kind != Tok::Punct('!')));
        assert_eq!(idents(src), vec!["let", "s", "g", "s"]);
    }

    #[test]
    fn raw_and_byte_strings() {
        assert_eq!(strs(r###"x(r#"a "quoted" b"#)"###), vec![r#"a "quoted" b"#]);
        assert_eq!(strs(r#"y(b"bytes")"#), vec!["bytes"]);
        assert_eq!(strs("z(r\"plain\")"), vec!["plain"]);
    }

    #[test]
    fn comments_are_captured_not_parsed() {
        let src = "// SAFETY: fine\nunsafe { f() } /* counter!(\"a.b\") */";
        let toks = tokenize(src);
        let comments: Vec<&str> = toks
            .iter()
            .filter_map(|t| match &t.kind {
                Tok::Comment(c) => Some(c.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(comments.len(), 2);
        assert!(comments[0].contains("SAFETY:"));
        // Macro-call text inside the block comment emits no idents.
        assert_eq!(idents(src), vec!["unsafe", "f"]);
    }

    #[test]
    fn nested_block_comments() {
        let toks = tokenize("/* a /* b */ c */ fn");
        assert_eq!(toks.len(), 2);
        assert!(matches!(toks[0].kind, Tok::Comment(_)));
        assert_eq!(toks[1].kind, Tok::Ident("fn".into()));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        // Char literals vanish into empty Str tokens; lifetimes lex as
        // a bare ident with the quote dropped.
        assert_eq!(idents("x<'a> = 'b'; s.push('\\n'); t('💡')"), vec!["x", "a", "s", "push", "t"]);
        // Tuple of char literals: the comma must survive.
        let toks = tokenize("('a', 'b')");
        assert_eq!(toks.iter().filter(|t| t.kind == Tok::Punct(',')).count(), 1);
    }

    #[test]
    fn numbers_are_single_tokens() {
        let toks = tokenize("1.5e-3 0x9E37 1_000 0..n");
        let nums = toks.iter().filter(|t| t.kind == Tok::Num).count();
        assert_eq!(nums, 4);
        // `..` survives as two puncts.
        assert_eq!(toks.iter().filter(|t| t.kind == Tok::Punct('.')).count(), 2);
    }

    #[test]
    fn unterminated_inputs_do_not_loop() {
        tokenize("\"unterminated");
        tokenize("/* unterminated");
        tokenize("r#\"unterminated");
        tokenize("'");
    }
}
