// Compliant twin of determinism_bad.rs: ordered containers, randomness
// only through the crate's seeded Rng, and timing pushed to the
// boundary via time_span! (which observes a histogram without feeding
// any scheduling decision).

use crate::util::rng::Rng;
use std::collections::BTreeMap;

fn schedule(rows: &[usize], rng: &mut Rng) -> Vec<usize> {
    crate::time_span!("bench.schedule_fixture_us", {
        let mut seen: BTreeMap<usize, usize> = BTreeMap::new();
        for (i, &r) in rows.iter().enumerate() {
            seen.insert(r, i);
        }
        let mut order: Vec<usize> = seen.values().copied().collect();
        let pivot = rng.next_usize(order.len().max(1));
        order.rotate_left(pivot);
        order
    })
}
