// Seeded violations for `determinism`. Self-tested under the virtual
// path rust/src/kernels/fixture.rs — kernels and the SA score path
// guarantee bitwise-identical results across runs and thread counts,
// which random-state hashing and wall-clock reads both break.

use std::collections::HashMap;

fn schedule(rows: &[usize]) -> Vec<usize> {
    let started = std::time::Instant::now();
    let mut seen: HashMap<usize, usize> = HashMap::new();
    for (i, &r) in rows.iter().enumerate() {
        seen.insert(r, i);
    }
    // Iteration order here differs run to run.
    let mut order: Vec<usize> = seen.values().copied().collect();
    if started.elapsed().as_micros() > 100 {
        order.reverse();
    }
    order
}
