// Compliant twin of safety_bad.rs: every unsafe carries its own
// immediately preceding SAFETY comment (multi-line blocks count as
// long as no code or blank line intervenes).

struct SendPtr(*mut f64);

// SAFETY: SendPtr is only constructed over a slice that outlives the
// scope, and each worker writes a disjoint index range.
unsafe impl Send for SendPtr {}

fn write_slot(p: &SendPtr, i: usize, v: f64) {
    let off = i * 2;
    // SAFETY: `off` is bounded by the pre-sized slot count checked by
    // the caller; no two callers share an index.
    unsafe {
        *p.0.add(off) = v;
    }
}
