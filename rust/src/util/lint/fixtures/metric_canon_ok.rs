// Compliant twin of metric_canon_bad.rs: canonical names, matching
// kinds, `_us` durations, and an allowlisted bench namespace.

fn handle_job() {
    crate::counter!("serve.jobs_total").inc();
    crate::gauge!("serve.linger_us").set(250.0);
    crate::time_span!("serve.featurize_us", { work() });
    crate::histogram!("serve.batch_size").observe(8);
    // `bench.` is allowlisted in lint.toml for scratch namespaces.
    crate::counter!("bench.anything_goes").inc();
}
