// Compliant twin of aliasing_bad.rs: instanced per-shard names are
// registered ONCE at thread start through the registry call form, and
// the handle is held for the life of the shard.

fn shard_loop(idx: usize) {
    let reg = crate::util::metrics::registry();
    let linger = reg.gauge(&format!("serve.shard_linger_us.{}", idx));
    let jobs = reg.counter(&format!("serve.shard_jobs_total.{}", idx));
    for _ in 0..4 {
        linger.set(250.0);
        jobs.inc();
    }
}
