// Compliant twin of panic_bad.rs: malformed input becomes an error
// value (the real serve path turns it into a JSON reply and bumps
// serve.errors_total); tests may still panic, and a clamped index can
// be waived with an explained lint:allow.

fn parse_request(line: &str) -> Result<(u64, usize), String> {
    let mut parts = line.split(',');
    let head = parts.next().ok_or("empty request")?;
    let id: u64 = head.parse().map_err(|_| format!("bad id {head:?}"))?;
    let k: usize = match parts.next() {
        Some(s) => s.parse().map_err(|_| format!("bad k {s:?}"))?,
        None => 5,
    };
    if k == 0 {
        return Err("k must be positive".to_string());
    }
    Ok((id, k))
}

fn bucket(counts: &[u64; 4], v: u64) -> u64 {
    let idx = (v as usize).min(3);
    // lint:allow(panic-audit) idx is clamped to the array bound above
    counts[idx]
}

#[cfg(test)]
mod tests {
    #[test]
    fn parses() {
        let (id, k) = super::parse_request("7,3").unwrap();
        assert_eq!((id, k), (7, 3));
    }
}
