// Seeded violations for `trace-canon`. Self-tested under the virtual
// path rust/src/coordinator/fixture.rs — span names are interned
// against util::trace::CANON at runtime, so a name the canon does not
// know becomes an inert span that silently records nothing, and a
// dynamic name defeats the static check entirely.

use crate::util::trace::{self, TraceCtx, TraceSpan};

fn handle(ctx: TraceCtx, phase: &'static str) {
    // Not in util::trace::CANON.
    crate::trace_span!("serve.rogue_phase", step());
    // Not `layer.name` shaped.
    let shapeless = TraceSpan::root("JustOneWord");
    drop(shapeless);
    // Dynamic name: unverifiable statically.
    crate::trace_span!(phase, step());
    // Backfilled span with a name the canon does not know.
    trace::record("serve.not_canonical", ctx, 0, 1, &[]);
}

fn step() {}
