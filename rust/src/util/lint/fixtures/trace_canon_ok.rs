// Compliant twin of trace_canon_bad.rs: every span name is a plain
// string literal, `layer.name` shaped, and present in
// util::trace::CANON, so the lint pass can prove statically that no
// call site ever degrades to an inert span.

use crate::util::trace::{self, TraceCtx, TraceSpan};

fn handle(ctx: TraceCtx) {
    crate::trace_span!("serve.score", step());
    let root = TraceSpan::root("pool.task").arg("task", 0);
    let child = TraceSpan::child("sa.chain", root.ctx());
    drop(child);
    drop(root);
    trace::record("serve.queue", ctx, 0, 1, &[("shard", 0)]);
}

fn step() {}
