// Seeded violations for `safety-comment`: unsafe without an adjacent
// SAFETY argument, and a comment separated from its unsafe by code.

struct SendPtr(*mut f64);

unsafe impl Send for SendPtr {}

fn write_slot(p: &SendPtr, i: usize, v: f64) {
    // SAFETY: this comment is orphaned by the statement below.
    let off = i * 2;
    unsafe {
        *p.0.add(off) = v;
    }
}
