// Seeded violations for `panic-audit`. Self-tested under the virtual
// path rust/src/coordinator/serve.rs — a panic-free zone: any of these
// would let a malformed client payload kill a batcher shard thread.

fn parse_request(line: &str) -> (u64, usize) {
    let parts: Vec<&str> = line.split(',').collect();
    // Indexing panics on an empty split.
    let head = parts[0];
    // unwrap panics on a non-numeric id.
    let id: u64 = head.parse().unwrap();
    // expect is the same panic wearing a message.
    let k: usize = parts.get(1).map(|s| s.parse().expect("k")).unwrap_or(5);
    if k == 0 {
        panic!("k must be positive");
    }
    (id, k)
}
