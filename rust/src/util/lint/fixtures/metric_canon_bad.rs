// Seeded violations for the `metric-canon` rule. This file is NOT
// compiled or scanned by the repo walk (lint.toml excludes fixtures/);
// it is include_str!-ed by the self-tests in util/lint/mod.rs.

fn handle_job() {
    // Off-canon name: nobody registered this with util::metrics::CANON.
    crate::counter!("bogus.name").inc();
    // Kind drift: serve.jobs_total is a counter in CANON.
    crate::gauge!("serve.jobs_total").set(1.0);
    // Shape violation: metric names are `layer.metric`, lowercase.
    crate::counter!("NoDotsHere").inc();
    // Duration histograms observe microseconds and must end `_us`.
    crate::time_span!("serve.batch_window", { work() });
}
