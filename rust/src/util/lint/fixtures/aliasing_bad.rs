// Seeded violation for `macro-instanced-aliasing`: the counter!-family
// macros cache ONE &'static handle in a per-call-site OnceLock, so a
// dynamic name aliases every shard onto whichever name registered
// first. This exact bug shape is documented in ROADMAP.md §Telemetry.

fn shard_loop(idx: usize) {
    for _ in 0..4 {
        crate::gauge!(&format!("serve.shard_linger_us.{idx}")).set(250.0);
    }
}
