//! The `cognate-lint` rule passes.
//!
//! Each rule is a pure function over a [`FileCtx`] (tokens + derived
//! line maps) that appends [`Finding`]s. Rules are lexical by design:
//! they key on token sequences, never on type information, so they can
//! run dependency-free in any environment — including the growth
//! container, which has no Rust toolchain at all.
//!
//! | rule | what it enforces |
//! |---|---|
//! | `metric-canon` | metric name literals match `util::metrics::CANON`, are `layer.metric` shaped, durations end `_us`, kinds agree |
//! | `macro-instanced-aliasing` | `counter!`-family name args are plain string literals (the per-call-site `OnceLock` aliases dynamic names) |
//! | `safety-comment` | every `unsafe` is immediately preceded by a `// SAFETY:` comment |
//! | `panic-audit` | no `unwrap()`/`expect(`/`panic!`/slice-indexing in the serve request path or metrics hot paths (outside `#[cfg(test)]`) |
//! | `determinism` | no `HashMap`/`HashSet`/`SystemTime`/`Instant::now` in `kernels/` or `search/anneal.rs` (use `util::rng::Rng`) |
//! | `trace-canon` | span name literals in `trace_span!` / `TraceSpan` constructors / `trace::record` are plain literals, `layer.name` shaped, and present in `util::trace::CANON` |
//!
//! Any finding can be suppressed with `// lint:allow(<rule>) reason` on
//! the same line or the line directly above — the reason is mandatory.

use super::tokens::{tokenize, Tok, Token};
use crate::util::metrics::{canon_kind, Kind, CANON};
use std::collections::{BTreeMap, BTreeSet};

pub const RULE_METRIC_CANON: &str = "metric-canon";
pub const RULE_ALIASING: &str = "macro-instanced-aliasing";
pub const RULE_SAFETY: &str = "safety-comment";
pub const RULE_PANIC: &str = "panic-audit";
pub const RULE_DETERMINISM: &str = "determinism";
pub const RULE_TRACE_CANON: &str = "trace-canon";

pub const ALL_RULES: [&str; 6] = [
    RULE_METRIC_CANON,
    RULE_ALIASING,
    RULE_SAFETY,
    RULE_PANIC,
    RULE_DETERMINISM,
    RULE_TRACE_CANON,
];

/// One diagnostic, rendered as `path:line: rule: message`.
#[derive(Clone, Debug)]
pub struct Finding {
    pub path: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

impl Finding {
    pub fn render(&self) -> String {
        format!("{}:{}: {}: {}", self.path, self.line, self.rule, self.msg)
    }
}

/// Per-file lint state derived once, shared by every rule pass.
pub struct FileCtx {
    /// Repo-relative path with `/` separators (rules scope on it).
    pub path: String,
    toks: Vec<Token>,
    /// Indices into `toks` of non-comment tokens.
    sig: Vec<usize>,
    /// Concatenated comment text per line (block comments register on
    /// every line they span).
    comment_text: BTreeMap<u32, String>,
    /// Lines carrying at least one non-comment token.
    code_lines: BTreeSet<u32>,
    /// Inclusive line ranges of `#[cfg(test)]` items.
    test_spans: Vec<(u32, u32)>,
    /// `lint:allow(rule)` directives: line → (rule, reason-present).
    allows: BTreeMap<u32, Vec<(String, bool)>>,
}

impl FileCtx {
    pub fn new(path: &str, src: &str) -> FileCtx {
        let toks = tokenize(src);
        let mut sig = Vec::with_capacity(toks.len());
        let mut comment_text: BTreeMap<u32, String> = BTreeMap::new();
        let mut code_lines = BTreeSet::new();
        let mut allows: BTreeMap<u32, Vec<(String, bool)>> = BTreeMap::new();
        for (i, t) in toks.iter().enumerate() {
            match &t.kind {
                Tok::Comment(text) => {
                    for (off, part) in text.split('\n').enumerate() {
                        let line = t.line + off as u32;
                        let slot = comment_text.entry(line).or_default();
                        slot.push_str(part);
                        slot.push(' ');
                        for (rule, has_reason) in parse_allows(part) {
                            allows.entry(line).or_default().push((rule, has_reason));
                        }
                    }
                }
                _ => {
                    sig.push(i);
                    code_lines.insert(t.line);
                }
            }
        }
        let test_spans = find_test_spans(&toks, &sig);
        FileCtx { path: path.to_string(), toks, sig, comment_text, code_lines, test_spans, allows }
    }

    fn tok(&self, s: usize) -> Option<&Token> {
        self.sig.get(s).map(|&i| &self.toks[i])
    }

    fn kind(&self, s: usize) -> Option<&Tok> {
        self.tok(s).map(|t| &t.kind)
    }

    fn is_punct(&self, s: usize, c: char) -> bool {
        matches!(self.kind(s), Some(Tok::Punct(p)) if *p == c)
    }

    fn is_ident(&self, s: usize, name: &str) -> bool {
        matches!(self.kind(s), Some(Tok::Ident(id)) if id == name)
    }

    fn line(&self, s: usize) -> u32 {
        self.tok(s).map(|t| t.line).unwrap_or(0)
    }

    fn in_test_span(&self, line: u32) -> bool {
        self.test_spans.iter().any(|&(a, b)| (a..=b).contains(&line))
    }

    /// Significant-token index just past the delimiter that closes the
    /// `(` expected at `open` (supports nesting of all bracket kinds).
    fn past_matching_close(&self, open: usize) -> usize {
        let mut depth = 0usize;
        let mut s = open;
        while let Some(k) = self.kind(s) {
            match k {
                Tok::Punct('(') | Tok::Punct('[') | Tok::Punct('{') => depth += 1,
                Tok::Punct(')') | Tok::Punct(']') | Tok::Punct('}') => {
                    depth = depth.saturating_sub(1);
                    if depth == 0 {
                        return s + 1;
                    }
                }
                _ => {}
            }
            s += 1;
        }
        s
    }

    /// True when the finding at `line` is suppressed by a well-formed
    /// `// lint:allow(<rule>) reason` on that line or the line above.
    fn allowed(&self, rule: &str, line: u32) -> bool {
        [line, line.saturating_sub(1)].iter().any(|l| {
            self.allows
                .get(l)
                .is_some_and(|v| v.iter().any(|(r, reason)| r == rule && *reason))
        })
    }

    fn push(&self, out: &mut Vec<Finding>, rule: &'static str, line: u32, msg: String) {
        if !self.allowed(rule, line) {
            out.push(Finding { path: self.path.clone(), line, rule, msg });
        }
    }
}

/// Extract `lint:allow(rule)` directives from one comment line. The
/// boolean records whether a non-empty reason follows the closing paren
/// — an allow without a reason never suppresses anything.
fn parse_allows(comment: &str) -> Vec<(String, bool)> {
    let mut out = Vec::new();
    let mut rest = comment;
    while let Some(at) = rest.find("lint:allow(") {
        rest = &rest[at + "lint:allow(".len()..];
        let Some(close) = rest.find(')') else { break };
        let rule = rest[..close].trim().to_string();
        rest = &rest[close + 1..];
        let reason_end = rest.find("lint:allow(").unwrap_or(rest.len());
        let has_reason = !rest[..reason_end].trim().is_empty();
        if !rule.is_empty() {
            out.push((rule, has_reason));
        }
    }
    out
}

/// Line spans of items under `#[cfg(test)]` (the attribute's line down
/// to the closing brace of the item body). Items without a brace body
/// (`use`, type aliases) contribute no span.
fn find_test_spans(toks: &[Token], sig: &[usize]) -> Vec<(u32, u32)> {
    let kind = |s: usize| sig.get(s).map(|&i| &toks[i].kind);
    let is_p = |s: usize, c: char| matches!(kind(s), Some(Tok::Punct(p)) if *p == c);
    let mut spans = Vec::new();
    let mut s = 0usize;
    while s < sig.len() {
        // `# [ cfg ( … test … ) ]`
        let is_cfg_test = is_p(s, '#')
            && is_p(s + 1, '[')
            && matches!(kind(s + 2), Some(Tok::Ident(id)) if id == "cfg")
            && is_p(s + 3, '(')
            && {
                let mut t = s + 4;
                let mut depth = 1usize;
                let mut seen_test = false;
                while depth > 0 {
                    match kind(t) {
                        None => break,
                        Some(Tok::Punct('(')) => depth += 1,
                        Some(Tok::Punct(')')) => depth -= 1,
                        Some(Tok::Ident(id)) if id == "test" => seen_test = true,
                        _ => {}
                    }
                    t += 1;
                }
                seen_test
            };
        if !is_cfg_test {
            s += 1;
            continue;
        }
        let start_line = toks[sig[s]].line;
        // Find the item body `{ … }` (give up at `;` — no body).
        let mut t = s + 4;
        loop {
            match kind(t) {
                None => return spans,
                Some(Tok::Punct(';')) => break,
                Some(Tok::Punct('{')) => {
                    let mut depth = 0usize;
                    while let Some(k) = kind(t) {
                        match k {
                            Tok::Punct('{') => depth += 1,
                            Tok::Punct('}') => {
                                depth -= 1;
                                if depth == 0 {
                                    spans.push((start_line, toks[sig[t]].line));
                                    break;
                                }
                            }
                            _ => {}
                        }
                        t += 1;
                    }
                    break;
                }
                _ => t += 1,
            }
        }
        s = t.max(s + 1);
    }
    spans
}

// ---- rule: metric-canon ----------------------------------------------------

/// Normalize a `format!` template to the canon's instanced form:
/// `serve.shard_jobs_total.{}` → `serve.shard_jobs_total.<i>`.
fn normalize_instanced(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    let mut depth = 0usize;
    for c in name.chars() {
        match c {
            '{' => {
                if depth == 0 {
                    out.push_str("<i>");
                }
                depth += 1;
            }
            '}' => depth = depth.saturating_sub(1),
            c if depth == 0 => out.push(c),
            _ => {}
        }
    }
    out
}

/// `layer.metric` shape: ≥ 2 dot-separated segments, each nonempty and
/// either `[a-z0-9_]+` or the instanced marker `<i>`.
fn is_canon_shaped(name: &str) -> bool {
    let segs: Vec<&str> = name.split('.').collect();
    segs.len() >= 2
        && segs.iter().all(|s| {
            *s == "<i>"
                || (!s.is_empty()
                    && s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'))
        })
}

fn kind_name(k: Kind) -> &'static str {
    match k {
        Kind::Counter => "counter",
        Kind::Gauge => "gauge",
        Kind::Histogram => "histogram",
    }
}

/// Metric-name checks shared by the macro and `registry()` call forms.
#[allow(clippy::too_many_arguments)]
fn check_metric_name(
    ctx: &FileCtx,
    out: &mut Vec<Finding>,
    used: &mut BTreeSet<String>,
    line: u32,
    name: &str,
    expect: Kind,
    via: &str,
    allow_prefixes: &[String],
) {
    if !is_canon_shaped(name) {
        ctx.push(
            out,
            RULE_METRIC_CANON,
            line,
            format!("metric name {name:?} is not `layer.metric` shaped (lowercase dotted segments)"),
        );
        return;
    }
    match canon_kind(name) {
        Some(k) => {
            used.insert(name.to_string());
            if k != expect {
                ctx.push(
                    out,
                    RULE_METRIC_CANON,
                    line,
                    format!(
                        "{name:?} is a {} in util::metrics::CANON but is used here via {via} (a {})",
                        kind_name(k),
                        kind_name(expect)
                    ),
                );
            }
        }
        None => {
            if !allow_prefixes.iter().any(|p| name.starts_with(p.as_str())) {
                ctx.push(
                    out,
                    RULE_METRIC_CANON,
                    line,
                    format!(
                        "{name:?} is not in util::metrics::CANON — add it there (and to the \
                         ROADMAP table) in the same PR, or allowlist its prefix in lint.toml"
                    ),
                );
            }
        }
    }
}

fn macro_kind(name: &str) -> Option<Kind> {
    match name {
        "counter" => Some(Kind::Counter),
        "gauge" => Some(Kind::Gauge),
        "histogram" | "time_span" => Some(Kind::Histogram),
        _ => None,
    }
}

/// Rules 1 + 2 share one walk over the macro / registry call sites.
/// `used` accumulates canon names referenced anywhere in the corpus for
/// the unused-entry check in `lint_repo`.
pub fn check_metrics_and_aliasing(
    ctx: &FileCtx,
    allow_prefixes: &[String],
    used: &mut BTreeSet<String>,
    out: &mut Vec<Finding>,
) {
    for s in 0..ctx.sig.len() {
        // Macro form: `name ! ( … )`.
        if let Some(Tok::Ident(mac)) = ctx.kind(s) {
            if let Some(expect) = macro_kind(mac) {
                if ctx.is_punct(s + 1, '!') && ctx.is_punct(s + 2, '(') {
                    let line = ctx.line(s);
                    match ctx.kind(s + 3) {
                        // `$name` inside macro_rules! definitions.
                        Some(Tok::Punct('$')) => {}
                        Some(Tok::Str(name)) => {
                            let name = name.clone();
                            check_metric_name(
                                ctx,
                                out,
                                used,
                                line,
                                &name,
                                expect,
                                &format!("{mac}!"),
                                allow_prefixes,
                            );
                            if mac == "time_span" && !name.ends_with("_us") {
                                ctx.push(
                                    out,
                                    RULE_METRIC_CANON,
                                    line,
                                    format!(
                                        "time_span! observes microseconds — {name:?} must end in `_us`"
                                    ),
                                );
                            }
                            if mac == "histogram" {
                                let after = ctx.past_matching_close(s + 2);
                                if ctx.is_punct(after, '.')
                                    && ctx.is_ident(after + 1, "observe_duration")
                                    && !name.ends_with("_us")
                                {
                                    ctx.push(
                                        out,
                                        RULE_METRIC_CANON,
                                        line,
                                        format!(
                                            "duration histogram {name:?} must end in `_us` \
                                             (observe_duration records microseconds)"
                                        ),
                                    );
                                }
                            }
                        }
                        _ => {
                            ctx.push(
                                out,
                                RULE_ALIASING,
                                line,
                                format!(
                                    "{mac}! caches ONE name per call site in a OnceLock — a \
                                     dynamic name aliases every instance onto the first \
                                     registration; pass a plain string literal, or register \
                                     instanced names once via registry().{}(&format!(…)) and \
                                     hold the handle",
                                    kind_name(expect)
                                ),
                            );
                        }
                    }
                    continue;
                }
            }
        }
        // Registry-call form: `. counter|gauge|histogram ( … )`.
        if s > 0 && ctx.is_punct(s - 1, '.') {
            if let Some(Tok::Ident(meth)) = ctx.kind(s) {
                let Some(expect) = macro_kind(meth) else { continue };
                if meth == "time_span" || !ctx.is_punct(s + 1, '(') {
                    continue;
                }
                let line = ctx.line(s);
                // Inspect the argument tokens for a resolvable name.
                let close = ctx.past_matching_close(s + 1);
                let mut t = s + 2;
                while ctx.is_punct(t, '&') {
                    t += 1;
                }
                if let Some(Tok::Str(name)) = ctx.kind(t) {
                    let name = name.clone();
                    check_metric_name(
                        ctx, out, used, line, &name, expect,
                        &format!(".{meth}()"), allow_prefixes,
                    );
                } else if ctx.is_ident(t, "format")
                    && ctx.is_punct(t + 1, '!')
                    && ctx.is_punct(t + 2, '(')
                {
                    if let Some(Tok::Str(template)) = ctx.kind(t + 3) {
                        let name = normalize_instanced(template);
                        check_metric_name(
                            ctx, out, used, line, &name, expect,
                            &format!(".{meth}(&format!(…))"), allow_prefixes,
                        );
                    }
                }
                // Anything else (a plain variable) is unresolvable
                // statically — skipped, the runtime registry still
                // type-checks it.
                let _ = close;
            }
        }
    }
}

// ---- rule: safety-comment --------------------------------------------------

pub fn check_safety_comments(ctx: &FileCtx, out: &mut Vec<Finding>) {
    for s in 0..ctx.sig.len() {
        if !ctx.is_ident(s, "unsafe") {
            continue;
        }
        let line = ctx.line(s);
        // Same-line trailing/leading comment counts, then walk up over
        // the directly attached comment block (no blank or code lines
        // in between — "immediately preceding" is the contract).
        let mut found = ctx
            .comment_text
            .get(&line)
            .is_some_and(|c| c.contains("SAFETY:"));
        let mut l = line.saturating_sub(1);
        while !found && l > 0 {
            match ctx.comment_text.get(&l) {
                Some(c) if !ctx.code_lines.contains(&l) => {
                    found = c.contains("SAFETY:");
                    if found {
                        break;
                    }
                    l -= 1;
                }
                _ => break,
            }
        }
        if !found {
            ctx.push(
                out,
                RULE_SAFETY,
                line,
                "`unsafe` without an immediately preceding `// SAFETY:` comment arguing the \
                 invariant"
                    .to_string(),
            );
        }
    }
}

// ---- rule: panic-audit -----------------------------------------------------

/// Files whose non-test code must stay panic-free: the serve request
/// path and the metrics hot paths.
pub fn panic_audit_applies(path: &str) -> bool {
    path.ends_with("coordinator/serve.rs") || path.ends_with("util/metrics.rs")
}

/// Puncts/keywords before `[` that mean "not an indexing expression"
/// (type syntax, array literals, attributes, slice patterns, macros).
fn is_index_context(prev: Option<&Tok>) -> bool {
    match prev {
        Some(Tok::Ident(id)) => {
            !matches!(id.as_str(), "in" | "if" | "else" | "match" | "return" | "mut" | "dyn" | "as")
        }
        Some(Tok::Punct(')')) | Some(Tok::Punct(']')) => true,
        _ => false,
    }
}

pub fn check_panic_audit(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if !panic_audit_applies(&ctx.path) {
        return;
    }
    for s in 0..ctx.sig.len() {
        let line = ctx.line(s);
        if ctx.in_test_span(line) {
            continue;
        }
        match ctx.kind(s) {
            Some(Tok::Ident(id)) if (id == "unwrap" || id == "expect") => {
                if s > 0 && ctx.is_punct(s - 1, '.') && ctx.is_punct(s + 1, '(') {
                    ctx.push(
                        out,
                        RULE_PANIC,
                        line,
                        format!(
                            ".{id}() can panic — this file is a panic-free zone (a malformed \
                             request must become an error reply, not kill a shard thread); \
                             return a Result or use unwrap_or/_else"
                        ),
                    );
                }
            }
            Some(Tok::Ident(id)) if id == "panic" => {
                if ctx.is_punct(s + 1, '!') {
                    ctx.push(
                        out,
                        RULE_PANIC,
                        line,
                        "panic! in a panic-free zone — bump serve.errors_total and reply with \
                         JSON instead"
                            .to_string(),
                    );
                }
            }
            Some(Tok::Punct('[')) => {
                if s > 0 && is_index_context(ctx.kind(s - 1)) {
                    ctx.push(
                        out,
                        RULE_PANIC,
                        line,
                        "slice indexing can panic on out-of-bounds — use .get()/.first() (or \
                         iterators) in panic-free zones"
                            .to_string(),
                    );
                }
            }
            _ => {}
        }
    }
}

// ---- rule: determinism -----------------------------------------------------

/// Modules whose score paths must stay bitwise-deterministic and
/// resume-safe: the executable kernels and the SA searcher.
pub fn determinism_applies(path: &str) -> bool {
    path.contains("/kernels/") || path.ends_with("search/anneal.rs")
}

pub fn check_determinism(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if !determinism_applies(&ctx.path) {
        return;
    }
    for s in 0..ctx.sig.len() {
        let Some(Tok::Ident(id)) = ctx.kind(s) else { continue };
        let line = ctx.line(s);
        match id.as_str() {
            "HashMap" | "HashSet" => ctx.push(
                out,
                RULE_DETERMINISM,
                line,
                format!(
                    "{id} iteration order is nondeterministic and would break the bitwise \
                     kernel / SA-resume guarantees — use BTreeMap/BTreeSet or index-keyed Vecs"
                ),
            ),
            "SystemTime" => ctx.push(
                out,
                RULE_DETERMINISM,
                line,
                "SystemTime in a deterministic score path — derive decisions from \
                 util::rng::Rng seeded by the caller, and time at the boundary with time_span!"
                    .to_string(),
            ),
            "Instant" => {
                if ctx.is_punct(s + 1, ':')
                    && ctx.is_punct(s + 2, ':')
                    && ctx.is_ident(s + 3, "now")
                {
                    ctx.push(
                        out,
                        RULE_DETERMINISM,
                        line,
                        "Instant::now in a deterministic score path — wall time must not feed \
                         kernels or SA decisions; time at the boundary with time_span! and \
                         randomize only through util::rng::Rng"
                            .to_string(),
                    );
                }
            }
            _ => {}
        }
    }
}

// ---- rule: trace-canon -----------------------------------------------------

/// Shared name checks for every span-name-bearing form: must be
/// `layer.name` shaped and present in `util::trace::CANON` (unknown
/// names degrade to inert spans at runtime — silently missing data —
/// so the drift is caught here instead).
fn check_trace_name(ctx: &FileCtx, out: &mut Vec<Finding>, line: u32, name: &str, via: &str) {
    if !is_canon_shaped(name) {
        ctx.push(
            out,
            RULE_TRACE_CANON,
            line,
            format!("trace span name {name:?} is not `layer.name` shaped (lowercase dotted segments)"),
        );
        return;
    }
    if crate::util::trace::canon_idx(name).is_none() {
        ctx.push(
            out,
            RULE_TRACE_CANON,
            line,
            format!(
                "{name:?} is not in util::trace::CANON — an unknown name makes {via} an inert \
                 span that silently records nothing; add the name to the canon (and the ROADMAP \
                 tracing section) in the same PR"
            ),
        );
    }
}

/// First-argument check shared by the macro and constructor forms:
/// `open` indexes the `(`. `$name` (macro_rules bodies) is exempt;
/// anything that is not a plain string literal defeats the static
/// check and is itself a finding.
fn check_trace_arg(ctx: &FileCtx, out: &mut Vec<Finding>, open: usize, via: &str) {
    let line = ctx.line(open);
    match ctx.kind(open + 1) {
        Some(Tok::Punct('$')) => {}
        Some(Tok::Str(name)) => {
            let name = name.clone();
            check_trace_name(ctx, out, line, &name, via);
        }
        _ => ctx.push(
            out,
            RULE_TRACE_CANON,
            line,
            format!(
                "{via} must be handed a plain string-literal span name so the canon check can \
                 run statically (dynamic names also defeat the zero-alloc name interning)"
            ),
        ),
    }
}

/// `TraceSpan` constructors whose first argument is a span name.
const TRACE_CTORS: [&str; 5] = ["root", "root_at", "root_with_id", "child", "begin"];

pub fn check_trace_canon(ctx: &FileCtx, out: &mut Vec<Finding>) {
    for s in 0..ctx.sig.len() {
        let line = ctx.line(s);
        // Tests deliberately probe non-canonical names (inert-span
        // behaviour), so only non-test code is checked.
        if ctx.in_test_span(line) {
            continue;
        }
        // Macro form: `trace_span ! ( "name" , body )`.
        if ctx.is_ident(s, "trace_span") && ctx.is_punct(s + 1, '!') && ctx.is_punct(s + 2, '(') {
            check_trace_arg(ctx, out, s + 2, "trace_span!");
            continue;
        }
        // Constructor form: `TraceSpan :: <ctor> ( "name" , … )`.
        if ctx.is_ident(s, "TraceSpan")
            && ctx.is_punct(s + 1, ':')
            && ctx.is_punct(s + 2, ':')
            && ctx.is_punct(s + 4, '(')
        {
            if let Some(Tok::Ident(ctor)) = ctx.kind(s + 3) {
                if TRACE_CTORS.contains(&ctor.as_str()) {
                    let via = format!("TraceSpan::{ctor}");
                    check_trace_arg(ctx, out, s + 4, &via);
                }
            }
            continue;
        }
        // Backfill form: `trace :: record ( "name" , … )`.
        if ctx.is_ident(s, "record")
            && s >= 3
            && ctx.is_ident(s - 3, "trace")
            && ctx.is_punct(s - 2, ':')
            && ctx.is_punct(s - 1, ':')
            && ctx.is_punct(s + 1, '(')
        {
            check_trace_arg(ctx, out, s + 1, "trace::record");
        }
    }
}

/// Run every rule over one file. `used` collects canon-name references
/// for the corpus-level unused-entry check.
pub fn lint_file_ctx(
    ctx: &FileCtx,
    allow_prefixes: &[String],
    used: &mut BTreeSet<String>,
) -> Vec<Finding> {
    let mut out = Vec::new();
    check_metrics_and_aliasing(ctx, allow_prefixes, used, &mut out);
    check_safety_comments(ctx, &mut out);
    check_panic_audit(ctx, &mut out);
    check_determinism(ctx, &mut out);
    check_trace_canon(ctx, &mut out);
    out
}

/// Corpus finisher: every CANON entry must be referenced somewhere.
/// `def_lines` (collected while scanning `util/metrics.rs`) lets the
/// diagnostic point at the stale entry itself.
pub fn check_unused_canon(
    used: &BTreeSet<String>,
    def_lines: &BTreeMap<String, u32>,
    out: &mut Vec<Finding>,
) {
    for (name, _) in CANON {
        if !used.contains(*name) {
            out.push(Finding {
                path: "rust/src/util/metrics.rs".to_string(),
                line: def_lines.get(*name).copied().unwrap_or(0),
                rule: RULE_METRIC_CANON,
                msg: format!(
                    "CANON entry {name:?} is referenced by no call site — remove it or wire \
                     the metric up (the canon, the code, and the ROADMAP table must not drift)"
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(path: &str, src: &str) -> Vec<Finding> {
        let ctx = FileCtx::new(path, src);
        let mut used = BTreeSet::new();
        lint_file_ctx(&ctx, &[String::from("bench.")], &mut used)
    }

    fn rules_of(fs: &[Finding]) -> Vec<&'static str> {
        fs.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn canon_names_pass_and_bogus_names_fail() {
        let ok = run("rust/src/x.rs", r#"fn f() { crate::counter!("serve.jobs_total").inc(); }"#);
        assert!(ok.is_empty(), "{ok:?}");
        let bad = run("rust/src/x.rs", r#"fn f() { crate::counter!("bogus.name").inc(); }"#);
        assert_eq!(rules_of(&bad), vec![RULE_METRIC_CANON], "{bad:?}");
        assert_eq!(bad[0].line, 1);
    }

    #[test]
    fn kind_mismatch_and_shape_are_findings() {
        let kind = run("rust/src/x.rs", r#"fn f() { crate::gauge!("serve.jobs_total").set(0.0); }"#);
        assert_eq!(rules_of(&kind), vec![RULE_METRIC_CANON]);
        let shape = run("rust/src/x.rs", r#"fn f() { crate::counter!("NoDotsHere").inc(); }"#);
        assert_eq!(rules_of(&shape), vec![RULE_METRIC_CANON]);
        let dur = run("rust/src/x.rs", r#"fn f() { crate::time_span!("bench.block", 1); }"#);
        assert_eq!(rules_of(&dur), vec![RULE_METRIC_CANON], "{dur:?}");
    }

    #[test]
    fn allow_prefix_and_dollar_args_are_exempt() {
        assert!(run("rust/src/x.rs", r#"fn f() { crate::counter!("bench.anything").inc(); }"#)
            .is_empty());
        // `$name` in a macro_rules body must not trip the aliasing rule.
        assert!(run(
            "rust/src/x.rs",
            "macro_rules! c { ($name:expr) => { registry().counter($name) }; }"
        )
        .is_empty());
    }

    #[test]
    fn dynamic_macro_name_is_aliasing() {
        let f = run(
            "rust/src/x.rs",
            r#"fn f(i: usize) { for _ in 0..4 { crate::gauge!(&format!("serve.linger_us.{i}")).set(0.0); } }"#,
        );
        assert_eq!(rules_of(&f), vec![RULE_ALIASING], "{f:?}");
    }

    #[test]
    fn registry_format_call_normalizes_to_instanced_canon() {
        let ok = run(
            "rust/src/x.rs",
            r#"fn f(i: usize) { let c = registry().counter(&format!("serve.shard_jobs_total.{}", i)); c.inc(); }"#,
        );
        assert!(ok.is_empty(), "{ok:?}");
        let bad = run(
            "rust/src/x.rs",
            r#"fn f(i: usize) { let c = registry().counter(&format!("serve.rogue_total.{}", i)); c.inc(); }"#,
        );
        assert_eq!(rules_of(&bad), vec![RULE_METRIC_CANON]);
    }

    #[test]
    fn unsafe_needs_adjacent_safety_comment() {
        let ok = "// SAFETY: disjoint writes via the cursor.\nunsafe { w(); }";
        assert!(run("rust/src/x.rs", ok).is_empty());
        let gap = "// SAFETY: too far away.\n\nlet x = 1;\nunsafe { w(); }";
        assert_eq!(rules_of(&run("rust/src/x.rs", gap)), vec![RULE_SAFETY]);
        let none = "unsafe impl Send for X {}";
        assert_eq!(rules_of(&run("rust/src/x.rs", none)), vec![RULE_SAFETY]);
    }

    #[test]
    fn panic_audit_scopes_by_path_and_test_span() {
        let src = "fn f(v: &[u64]) -> u64 { v.first().copied().unwrap() }";
        assert!(run("rust/src/other.rs", src).is_empty(), "only scoped files are panic-free zones");
        let f = run("rust/src/coordinator/serve.rs", src);
        assert_eq!(rules_of(&f), vec![RULE_PANIC]);
        let tested = "#[cfg(test)]\nmod tests {\n fn g(v: &[u64]) -> u64 { v[0] }\n}";
        assert!(run("rust/src/coordinator/serve.rs", tested).is_empty());
    }

    #[test]
    fn indexing_flags_expressions_not_types() {
        let ty = "struct H { b: [u64; 4] } fn f() -> Vec<[u8; 2]> { vec![[0; 2]] }";
        assert!(run("rust/src/util/metrics.rs", ty).is_empty(), "{:?}", run("rust/src/util/metrics.rs", ty));
        let idx = "fn f(v: &[u64]) -> u64 { v[0] }";
        assert_eq!(rules_of(&run("rust/src/util/metrics.rs", idx)), vec![RULE_PANIC]);
    }

    #[test]
    fn determinism_scopes_and_fires() {
        let src = "use std::collections::HashMap;\nfn f() { let t = std::time::Instant::now(); }";
        assert!(run("rust/src/coordinator/serve.rs", src).is_empty());
        let f = run("rust/src/kernels/spmm.rs", src);
        assert_eq!(rules_of(&f), vec![RULE_DETERMINISM, RULE_DETERMINISM], "{f:?}");
        assert_eq!((f[0].line, f[1].line), (1, 2));
        let ok = "use crate::util::rng::Rng;\nfn f(r: &mut Rng) -> u64 { r.next_u64() }";
        assert!(run("rust/src/search/anneal.rs", ok).is_empty());
    }

    #[test]
    fn allow_with_reason_suppresses_without_reason_does_not() {
        let with = "// lint:allow(panic-audit) bucket_of clamps the index\nfn f(v: &[u64]) -> u64 { v[0] }";
        assert!(run("rust/src/util/metrics.rs", with).is_empty());
        let without = "// lint:allow(panic-audit)\nfn f(v: &[u64]) -> u64 { v[0] }";
        assert_eq!(rules_of(&run("rust/src/util/metrics.rs", without)), vec![RULE_PANIC]);
        let wrong_rule = "// lint:allow(determinism) misdirected\nfn f(v: &[u64]) -> u64 { v[0] }";
        assert_eq!(rules_of(&run("rust/src/util/metrics.rs", wrong_rule)), vec![RULE_PANIC]);
    }

    #[test]
    fn trace_canon_checks_macro_ctor_and_record_forms() {
        let ok = run(
            "rust/src/x.rs",
            r#"fn f() { crate::trace_span!("sa.chain", work()); }"#,
        );
        assert!(ok.is_empty(), "{ok:?}");
        let bad = run(
            "rust/src/x.rs",
            r#"fn f() { crate::trace_span!("sa.rogue", work()); }"#,
        );
        assert_eq!(rules_of(&bad), vec![RULE_TRACE_CANON], "{bad:?}");
        let shape = run(
            "rust/src/x.rs",
            r#"fn f() { let s = TraceSpan::root("NotShaped"); }"#,
        );
        assert_eq!(rules_of(&shape), vec![RULE_TRACE_CANON], "{shape:?}");
        let ctor_ok = run(
            "rust/src/x.rs",
            r#"fn f(c: TraceCtx) { let s = TraceSpan::child("serve.score", c); }"#,
        );
        assert!(ctor_ok.is_empty(), "{ctor_ok:?}");
        let rec = run(
            "rust/src/x.rs",
            r#"fn f(c: TraceCtx) { trace::record("serve.bogus", c, 0, 1, &[]); }"#,
        );
        assert_eq!(rules_of(&rec), vec![RULE_TRACE_CANON], "{rec:?}");
    }

    #[test]
    fn trace_canon_flags_dynamic_names_and_exempts_macro_dollars_and_tests() {
        let dynamic = run(
            "rust/src/x.rs",
            r#"fn f(name: &'static str) { crate::trace_span!(name, work()); }"#,
        );
        assert_eq!(rules_of(&dynamic), vec![RULE_TRACE_CANON], "{dynamic:?}");
        // `$name` in macro_rules bodies is how the macro itself expands.
        assert!(run(
            "rust/src/x.rs",
            "macro_rules! t { ($name:expr) => { TraceSpan::root($name) }; }"
        )
        .is_empty());
        // Tests probe inert behaviour with non-canonical names on purpose.
        let tested =
            "#[cfg(test)]\nmod tests {\n fn g() { let s = TraceSpan::root(\"not.canonical\"); }\n}";
        assert!(run("rust/src/x.rs", tested).is_empty());
        // Unqualified `record(` and plain fn defs must not match.
        assert!(run("rust/src/x.rs", "fn record(x: u64) -> u64 { x }").is_empty());
    }

    #[test]
    fn unused_canon_reports_stale_entries() {
        let mut used: BTreeSet<String> =
            CANON.iter().map(|(n, _)| n.to_string()).collect();
        let mut out = Vec::new();
        check_unused_canon(&used, &BTreeMap::new(), &mut out);
        assert!(out.is_empty());
        used.remove("sa.evals_total");
        check_unused_canon(&used, &BTreeMap::new(), &mut out);
        assert_eq!(rules_of(&out), vec![RULE_METRIC_CANON]);
        assert!(out[0].msg.contains("sa.evals_total"));
    }

    #[test]
    fn quoted_and_commented_violations_do_not_fire() {
        let src = r##"
// counter!("bogus.name") in a comment
fn f() { let s = "counter!(\"also.bogus\")"; let r = r#"panic!("no")"#; g(s, r); }
"##;
        assert!(run("rust/src/coordinator/serve.rs", src).is_empty());
    }
}
