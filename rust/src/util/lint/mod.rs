//! `cognate-lint`: a dependency-free static analysis pass over the
//! crate's own sources.
//!
//! The rules (see [`rules`]) mechanically enforce invariants that
//! previously lived only as ROADMAP prose: metric names must match
//! `util::metrics::CANON` (and the ROADMAP table must match both),
//! `counter!`-family macros must never be handed dynamic names, every
//! `unsafe` needs an adjacent `// SAFETY:` argument, the serve request
//! path and metrics hot paths stay panic-free, the kernels / SA
//! score paths stay deterministic, and every span name handed to
//! `trace_span!` / `TraceSpan` / `trace::record` is a literal present
//! in `util::trace::CANON`.
//!
//! Three front doors, all sharing [`lint_repo`]:
//!
//! - `cargo run --release --bin cognate_lint` — CLI with JSON summary
//! - `tests/lint.rs` — gates `cargo test -q` on zero findings at HEAD
//! - `scripts/verify.sh` — the `== lint ==` stage
//!
//! Per-repo configuration lives in `lint.toml` at the repo root (a
//! deliberately tiny TOML subset: `[section]` headers and
//! `key = ["…"]` string arrays). Inline escapes use
//! `// lint:allow(<rule>) reason` — the reason is mandatory.

pub mod rules;
pub mod tokens;

pub use rules::{Finding, ALL_RULES};

use crate::util::json::Json;
use crate::util::metrics::CANON;
use rules::{check_unused_canon, FileCtx, RULE_METRIC_CANON};
use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

/// Directories scanned under the repo root, in order.
pub const SCAN_DIRS: [&str; 4] = ["rust/src", "rust/benches", "rust/tests", "examples"];

/// Options loaded from `lint.toml` (all default to empty).
#[derive(Clone, Debug, Default)]
pub struct LintOptions {
    /// `[metric-canon] allow_prefixes`: name prefixes exempt from the
    /// canon lookup (bench/test namespaces).
    pub allow_prefixes: Vec<String>,
    /// `[scan] exclude`: repo-relative path substrings to skip —
    /// notably the seeded-violation fixtures under `util/lint/fixtures/`.
    pub exclude: Vec<String>,
}

impl LintOptions {
    /// Parse the `lint.toml` subset: `[section]` lines and
    /// `key = ["a", "b"]` string-array lines; `#` comments; anything
    /// else is ignored (unknown keys must not brick the linter).
    pub fn parse_toml(src: &str) -> LintOptions {
        let mut opts = LintOptions::default();
        let mut section = String::new();
        for raw in src.lines() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|r| r.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let Some((key, val)) = line.split_once('=') else { continue };
            let (key, val) = (key.trim(), val.trim());
            if !val.starts_with('[') {
                continue;
            }
            let items: Vec<String> = val
                .trim_start_matches('[')
                .trim_end_matches(']')
                .split(',')
                .map(|s| s.trim().trim_matches('"').to_string())
                .filter(|s| !s.is_empty())
                .collect();
            match (section.as_str(), key) {
                ("metric-canon", "allow_prefixes") => opts.allow_prefixes = items,
                ("scan", "exclude") => opts.exclude = items,
                _ => {}
            }
        }
        opts
    }

    pub fn load(root: &Path) -> LintOptions {
        match std::fs::read_to_string(root.join("lint.toml")) {
            Ok(src) => LintOptions::parse_toml(&src),
            Err(_) => LintOptions::default(),
        }
    }

    fn excluded(&self, rel: &str) -> bool {
        self.exclude.iter().any(|pat| rel.contains(pat.as_str()))
    }
}

/// Result of a full-repo run.
pub struct Report {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

impl Report {
    pub fn ok(&self) -> bool {
        self.findings.is_empty()
    }

    /// Machine-readable summary (sorted keys, stable across runs).
    pub fn to_json(&self) -> Json {
        let findings = self
            .findings
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("path", Json::Str(f.path.clone())),
                    ("line", Json::Num(f.line as f64)),
                    ("rule", Json::Str(f.rule.to_string())),
                    ("msg", Json::Str(f.msg.clone())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("ok", Json::Bool(self.ok())),
            ("files_scanned", Json::Num(self.files_scanned as f64)),
            ("findings", Json::Arr(findings)),
            ("rules", Json::arr_str(&ALL_RULES)),
        ])
    }

    /// Human-readable `path:line: rule: message` lines.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&f.render());
            out.push('\n');
        }
        out
    }
}

/// Lint one source text under a virtual repo-relative path. This is the
/// unit the fixture self-tests drive; corpus-level checks (unused canon
/// entries, ROADMAP drift) only run in [`lint_repo`].
pub fn lint_source(path: &str, src: &str, opts: &LintOptions) -> Vec<Finding> {
    let ctx = FileCtx::new(path, src);
    let mut used = BTreeSet::new();
    let mut findings = rules::lint_file_ctx(&ctx, &opts.allow_prefixes, &mut used);
    findings.sort_by(|a, b| a.line.cmp(&b.line).then(a.rule.cmp(b.rule)));
    findings
}

/// Walk up from `start` to the repo root, identified by the `rust/src`
/// tree plus `ROADMAP.md` (works whether the manifest lives at the
/// root or under `rust/`).
pub fn find_repo_root(start: &Path) -> Option<PathBuf> {
    let mut cur = Some(start);
    while let Some(dir) = cur {
        if dir.join("rust/src").is_dir() && dir.join("ROADMAP.md").is_file() {
            return Some(dir.to_path_buf());
        }
        cur = dir.parent();
    }
    None
}

/// Root discovery for the binary: `COGNATE_LINT_ROOT` wins, then the
/// current directory, then the build-time manifest dir.
pub fn discover_root() -> Option<PathBuf> {
    if let Ok(root) = std::env::var("COGNATE_LINT_ROOT") {
        return find_repo_root(Path::new(&root));
    }
    if let Ok(cwd) = std::env::current_dir() {
        if let Some(root) = find_repo_root(&cwd) {
            return Some(root);
        }
    }
    if let Ok(man) = std::env::var("CARGO_MANIFEST_DIR") {
        return find_repo_root(Path::new(&man));
    }
    None
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    let mut paths: Vec<PathBuf> = entries.flatten().map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            collect_rs_files(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

/// Line (1-based) of each CANON name's defining literal in
/// `util/metrics.rs`, so unused-entry diagnostics point at the entry.
fn canon_def_lines(metrics_src: &str) -> BTreeMap<String, u32> {
    let mut out = BTreeMap::new();
    for (idx, line) in metrics_src.lines().enumerate() {
        for (name, _) in CANON {
            let needle = format!("\"{name}\"");
            if line.contains(&needle) {
                out.entry(name.to_string()).or_insert(idx as u32 + 1);
            }
        }
    }
    out
}

/// Cross-check the ROADMAP metric table against CANON, both ways. Table
/// rows are `| `name` | kind | meaning |` — any backticked token in the
/// first cell whose kind cell is a metric kind is a declared name.
fn check_roadmap_table(roadmap: &str, out: &mut Vec<Finding>) {
    let kinds = ["counter", "gauge", "histogram"];
    let mut declared: BTreeMap<String, (u32, String)> = BTreeMap::new();
    for (idx, line) in roadmap.lines().enumerate() {
        let cells: Vec<&str> = line.split('|').map(str::trim).collect();
        // `| a | b | c |` splits into ["", a, b, c, ""].
        if cells.len() < 4 || !kinds.contains(&cells[2]) {
            continue;
        }
        let mut rest = cells[1];
        while let Some(open) = rest.find('`') {
            let Some(close) = rest[open + 1..].find('`') else { break };
            let name = &rest[open + 1..open + 1 + close];
            declared.insert(name.to_string(), (idx as u32 + 1, cells[2].to_string()));
            rest = &rest[open + 2 + close..];
        }
    }
    for (name, (line, kind)) in &declared {
        match crate::util::metrics::canon_kind(name) {
            None => out.push(Finding {
                path: "ROADMAP.md".to_string(),
                line: *line,
                rule: RULE_METRIC_CANON,
                msg: format!(
                    "ROADMAP table declares {name:?} but util::metrics::CANON does not — the \
                     table, the canon, and the code must move together"
                ),
            }),
            Some(k) => {
                let canon_kind_name = match k {
                    crate::util::metrics::Kind::Counter => "counter",
                    crate::util::metrics::Kind::Gauge => "gauge",
                    crate::util::metrics::Kind::Histogram => "histogram",
                };
                if canon_kind_name != kind {
                    out.push(Finding {
                        path: "ROADMAP.md".to_string(),
                        line: *line,
                        rule: RULE_METRIC_CANON,
                        msg: format!(
                            "ROADMAP table says {name:?} is a {kind} but CANON says {canon_kind_name}"
                        ),
                    });
                }
            }
        }
    }
    for (name, _) in CANON {
        if !declared.contains_key(*name) {
            out.push(Finding {
                path: "ROADMAP.md".to_string(),
                line: 0,
                rule: RULE_METRIC_CANON,
                msg: format!(
                    "CANON entry {name:?} is missing from the ROADMAP metric table — document \
                     it in the same PR that adds it"
                ),
            });
        }
    }
}

/// Lint the whole repo rooted at `root`: every `.rs` file under
/// [`SCAN_DIRS`], then the corpus-level canon/ROADMAP drift checks.
pub fn lint_repo(root: &Path) -> std::io::Result<Report> {
    let opts = LintOptions::load(root);
    let mut files = Vec::new();
    for dir in SCAN_DIRS {
        collect_rs_files(&root.join(dir), &mut files);
    }
    let mut findings = Vec::new();
    let mut used: BTreeSet<String> = BTreeSet::new();
    let mut def_lines = BTreeMap::new();
    let mut files_scanned = 0usize;
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        if opts.excluded(&rel) {
            continue;
        }
        let src = std::fs::read_to_string(path)?;
        if rel.ends_with("util/metrics.rs") {
            def_lines = canon_def_lines(&src);
        }
        let ctx = FileCtx::new(&rel, &src);
        findings.extend(rules::lint_file_ctx(&ctx, &opts.allow_prefixes, &mut used));
        files_scanned += 1;
    }
    check_unused_canon(&used, &def_lines, &mut findings);
    match std::fs::read_to_string(root.join("ROADMAP.md")) {
        Ok(roadmap) => check_roadmap_table(&roadmap, &mut findings),
        Err(e) => {
            return Err(std::io::Error::new(
                e.kind(),
                format!("ROADMAP.md unreadable under {}: {e}", root.display()),
            ))
        }
    }
    findings.sort_by(|a, b| {
        a.path.cmp(&b.path).then(a.line.cmp(&b.line)).then(a.rule.cmp(b.rule))
    });
    Ok(Report { findings, files_scanned })
}

// ---- fixture-driven self-tests --------------------------------------------
//
// Each rule ships a seeded-violation fixture and a compliant twin under
// `fixtures/`. The repo walk skips that directory (`[scan] exclude` in
// lint.toml); these tests are the only consumer, via include_str!, so a
// regression in any rule turns `cargo test -q` red with the exact
// diagnostic the CLI would print.

#[cfg(test)]
mod fixture_tests {
    use super::*;

    fn opts() -> LintOptions {
        LintOptions {
            allow_prefixes: vec!["bench.".into(), "metrics.test.".into(), "t.".into()],
            exclude: vec![],
        }
    }

    /// The bad fixture must fire exactly `rule`; the ok twin must be
    /// silent. Virtual paths put path-scoped rules in scope.
    fn check_pair(rule: &str, vpath: &str, bad: &str, ok: &str) {
        let bad_findings = lint_source(vpath, bad, &opts());
        assert!(
            bad_findings.iter().any(|f| f.rule == rule),
            "fixture for {rule} did not fire: {bad_findings:?}"
        );
        assert!(
            bad_findings.iter().all(|f| f.rule == rule),
            "fixture for {rule} fired extra rules: {bad_findings:?}"
        );
        for f in &bad_findings {
            assert_eq!(f.path, vpath);
            assert!(f.line > 0, "finding without a line: {f:?}");
        }
        let ok_findings = lint_source(vpath, ok, &opts());
        assert!(ok_findings.is_empty(), "compliant twin for {rule} fired: {ok_findings:?}");
    }

    #[test]
    fn metric_canon_fixture() {
        check_pair(
            "metric-canon",
            "rust/src/coordinator/fixture.rs",
            include_str!("fixtures/metric_canon_bad.rs"),
            include_str!("fixtures/metric_canon_ok.rs"),
        );
    }

    #[test]
    fn aliasing_fixture() {
        check_pair(
            "macro-instanced-aliasing",
            "rust/src/coordinator/fixture.rs",
            include_str!("fixtures/aliasing_bad.rs"),
            include_str!("fixtures/aliasing_ok.rs"),
        );
    }

    #[test]
    fn safety_fixture() {
        check_pair(
            "safety-comment",
            "rust/src/util/fixture.rs",
            include_str!("fixtures/safety_bad.rs"),
            include_str!("fixtures/safety_ok.rs"),
        );
    }

    #[test]
    fn panic_fixture() {
        check_pair(
            "panic-audit",
            "rust/src/coordinator/serve.rs",
            include_str!("fixtures/panic_bad.rs"),
            include_str!("fixtures/panic_ok.rs"),
        );
    }

    #[test]
    fn determinism_fixture() {
        check_pair(
            "determinism",
            "rust/src/kernels/fixture.rs",
            include_str!("fixtures/determinism_bad.rs"),
            include_str!("fixtures/determinism_ok.rs"),
        );
    }

    #[test]
    fn trace_canon_fixture() {
        check_pair(
            "trace-canon",
            "rust/src/coordinator/fixture.rs",
            include_str!("fixtures/trace_canon_bad.rs"),
            include_str!("fixtures/trace_canon_ok.rs"),
        );
    }
}

#[cfg(test)]
mod config_tests {
    use super::*;

    #[test]
    fn toml_subset_parses_sections_and_arrays() {
        let src = r#"
# cognate-lint configuration
[metric-canon]
allow_prefixes = ["bench.", "metrics.test.", "t."]  # test namespaces

[scan]
exclude = ["util/lint/fixtures/"]
"#;
        let opts = LintOptions::parse_toml(src);
        assert_eq!(opts.allow_prefixes, vec!["bench.", "metrics.test.", "t."]);
        assert_eq!(opts.exclude, vec!["util/lint/fixtures/"]);
        assert!(opts.excluded("rust/src/util/lint/fixtures/safety_bad.rs"));
        assert!(!opts.excluded("rust/src/util/lint/mod.rs"));
    }

    #[test]
    fn unknown_keys_and_garbage_are_ignored() {
        let opts = LintOptions::parse_toml("[future]\nknob = [\"x\"]\nnot toml at all\n");
        assert!(opts.allow_prefixes.is_empty());
        assert!(opts.exclude.is_empty());
    }

    #[test]
    fn roadmap_cross_check_flags_drift_both_ways() {
        // A name the canon doesn't know.
        let mut out = Vec::new();
        let table = "| name | kind | meaning |\n|---|---|---|\n| `rogue.metric` | counter | ? |\n";
        check_roadmap_table(table, &mut out);
        assert!(out.iter().any(|f| f.msg.contains("rogue.metric")), "{out:?}");
        // A canon entry the table omits (every entry, with this table).
        assert!(out.iter().any(|f| f.msg.contains("serve.jobs_total")), "{out:?}");
        // Kind drift.
        let mut out2 = Vec::new();
        let table2 = "| `serve.jobs_total` | gauge | drifted |\n";
        check_roadmap_table(table2, &mut out2);
        assert!(
            out2.iter().any(|f| f.msg.contains("gauge") && f.msg.contains("counter")),
            "{out2:?}"
        );
    }

    #[test]
    fn report_json_is_parseable_and_sorted() {
        let report = Report {
            findings: vec![Finding {
                path: "rust/src/x.rs".into(),
                line: 3,
                rule: "metric-canon",
                msg: "m".into(),
            }],
            files_scanned: 7,
        };
        let s = report.to_json().to_string();
        let back = Json::parse(&s).expect("report JSON must parse");
        assert_eq!(back.to_string(), s);
        assert!(report.render().contains("rust/src/x.rs:3: metric-canon: m"));
        assert!(!report.ok());
    }
}
