//! Hand-rolled micro/end-to-end benchmark harness (criterion is not
//! available offline). Benches under `rust/benches/` use
//! `harness = false` and drive this: warmup, timed iterations, and a
//! one-line report with mean / p50 / p95 plus optional throughput.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<42} iters={:<4} mean={} p50={} p95={} min={}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.p50_s),
            fmt_time(self.p95_s),
            fmt_time(self.min_s),
        );
    }

    pub fn report_throughput(&self, items: f64, unit: &str) {
        println!(
            "bench {:<42} iters={:<4} mean={} p50={} thrpt={:.1} {unit}/s",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.p50_s),
            items / self.mean_s,
        );
    }
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:7.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:7.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:7.2}ms", s * 1e3)
    } else {
        format!("{s:7.3}s ")
    }
}

/// Run `f` with `warmup` untimed iterations then up to `iters` timed
/// iterations (stopping early after `max_secs` of measurement).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, max_secs: f64, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    let budget = Instant::now();
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
        if budget.elapsed().as_secs_f64() > max_secs {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean_s: mean,
        p50_s: samples[n / 2],
        p95_s: samples[(n as f64 * 0.95) as usize % n.max(1)],
        min_s: samples[0],
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("noop-ish", 1, 16, 1.0, || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(r.iters >= 1);
        assert!(r.mean_s >= 0.0);
        assert!(r.p50_s <= r.p95_s + 1e-9);
    }

    #[test]
    fn respects_time_budget() {
        let t = Instant::now();
        let _ = bench("sleepy", 0, 1000, 0.05, || {
            std::thread::sleep(std::time::Duration::from_millis(10));
        });
        assert!(t.elapsed().as_secs_f64() < 1.0);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2e-9).contains("ns"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_time(2e-3).contains("ms"));
        assert!(fmt_time(2.0).contains('s'));
    }
}
