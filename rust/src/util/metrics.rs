//! Lock-free telemetry substrate: a global registry of named counters,
//! gauges, and fixed-bucket (log2) histograms, all backed by
//! `AtomicU64` with `Relaxed` ordering.
//!
//! Design constraints (the whole point of this module):
//! * **No locks and no allocation after registration.** The registry's
//!   `Mutex` is touched exactly once per call site: the `counter!` /
//!   `gauge!` / `histogram!` macros cache the `&'static` handle in a
//!   per-call-site `OnceLock`, so the hot path is one atomic load plus
//!   one `fetch_add` (single-digit nanoseconds — `bench_metrics`
//!   enforces < 50ns and `scripts/verify.sh` runs it as a gate).
//! * **Registration is idempotent.** Two call sites naming the same
//!   metric share one leaked cell, so `serve.jobs_total` can be bumped
//!   from anywhere and snapshot once.
//! * **Snapshots are best-effort consistent.** Reads are not atomic
//!   across metrics; a snapshot taken while updates are in flight may
//!   see a counter and its histogram momentarily out of step. Callers
//!   that assert exact invariants (tests) must quiesce first.
//!
//! Histograms bucket by log2 of the observed value — by convention
//! microseconds for latency (`*_us` names) and raw counts otherwise —
//! so 64 buckets cover the full `u64` range with no configuration and
//! no allocation. Quantiles are approximate (geometric bucket
//! midpoints), which is plenty for "where does the time go".
//!
//! Span timing: `time_span!("stage.us", { work })` observes the block's
//! wall time into the named histogram and returns the block's value;
//! `Span::new` is the RAII form for early-return-heavy code.
//!
//! Instanced metrics (one per shard / worker, e.g.
//! `serve.shard_jobs_total.<i>`): the macros cache ONE name per call
//! site, so a dynamic name through `counter!` would silently alias
//! every instance onto whichever name registered first. Register those
//! through `registry().counter(&format!(...))` once at thread start
//! and hold the returned `&'static` handle — same lock-free hot path,
//! one registration per instance instead of per call site.
//!
//! Naming is governed by [`CANON`]: the full production name table,
//! statically enforced by `cognate-lint` (`cargo run --bin
//! cognate_lint`) against every call site and the ROADMAP.md table.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

// ---- canonical names ------------------------------------------------------

/// Metric kinds, as declared in [`CANON`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Counter,
    Gauge,
    Histogram,
}

/// The canonical metric-name table: every production metric the crate
/// emits, in `layer.metric` form, with duration histograms ending
/// `_us`. Instanced (per-shard) names carry a literal `<i>` segment.
///
/// This table is load-bearing: the `cognate-lint` metric-canon rule
/// checks every `counter!`/`gauge!`/`histogram!`/`time_span!` literal
/// and `registry().counter(&format!(…))` template against it, flags
/// entries no call site references, and cross-checks the ROADMAP.md
/// metric table both ways. Adding a metric means updating all three in
/// the same PR — `cargo test -q` (via `tests/lint.rs`) fails otherwise.
pub const CANON: &[(&str, Kind)] = &[
    ("serve.jobs_total", Kind::Counter),
    ("serve.errors_total", Kind::Counter),
    ("serve.connections_total", Kind::Counter),
    ("serve.stats_requests_total", Kind::Counter),
    ("serve.trace_requests_total", Kind::Counter),
    ("serve.queue_wait_us", Kind::Histogram),
    ("serve.batch_size", Kind::Histogram),
    ("serve.featurize_us", Kind::Histogram),
    ("serve.score_us", Kind::Histogram),
    ("serve.linger_us", Kind::Gauge),
    ("serve.shard_linger_us.<i>", Kind::Gauge),
    ("serve.shard_jobs_total.<i>", Kind::Counter),
    ("serve.router_depth", Kind::Histogram),
    ("serve.router_overflow_total", Kind::Counter),
    ("train.steps_total", Kind::Counter),
    ("train.step_us", Kind::Histogram),
    ("train.pair_sample_us", Kind::Histogram),
    ("train.loss", Kind::Gauge),
    ("train.val_prl", Kind::Gauge),
    ("train.val_opa", Kind::Gauge),
    ("train.val_ktau", Kind::Gauge),
    ("sa.evals_total", Kind::Counter),
    ("sa.accept_rate", Kind::Gauge),
    ("sa.best_score", Kind::Gauge),
    ("sa.chain_us", Kind::Histogram),
    ("kernels.partition_imbalance", Kind::Gauge),
    ("pool.tasks_total", Kind::Counter),
    ("pool.task_wait_us", Kind::Histogram),
    ("dataset.matrix_eval_us", Kind::Histogram),
    ("dataset.lpt_skew", Kind::Gauge),
    ("trace.dropped_total", Kind::Counter),
];

/// Exact-match lookup into [`CANON`] (instanced names match only their
/// `<i>` template form — callers normalize `format!` templates first).
pub fn canon_kind(name: &str) -> Option<Kind> {
    CANON.iter().find(|(n, _)| *n == name).map(|&(_, k)| k)
}

// ---- metric cells ---------------------------------------------------------

/// Monotone event count.
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub const fn new() -> Counter {
        Counter { v: AtomicU64::new(0) }
    }
    #[inline]
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }
    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
    pub fn reset(&self) {
        self.v.store(0, Ordering::Relaxed);
    }
}

impl Default for Counter {
    fn default() -> Self {
        Self::new()
    }
}

/// Last-writer-wins `f64` value (stored as bits in an `AtomicU64`).
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    pub const fn new() -> Gauge {
        // 0u64 is the bit pattern of 0.0f64.
        Gauge { bits: AtomicU64::new(0) }
    }
    #[inline]
    pub fn set(&self, x: f64) {
        self.bits.store(x.to_bits(), Ordering::Relaxed);
    }
    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
    pub fn reset(&self) {
        self.set(0.0);
    }
}

impl Default for Gauge {
    fn default() -> Self {
        Self::new()
    }
}

/// Number of log2 buckets: bucket 0 holds the value 0, bucket `i` (≥ 1)
/// holds values in `[2^(i-1), 2^i)`; the top bucket also absorbs the
/// overflow tail.
pub const HIST_BUCKETS: usize = 64;

/// Fixed-bucket log2 histogram of `u64` observations.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Histogram {
    pub const fn new() -> Histogram {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram {
            buckets: [ZERO; HIST_BUCKETS],
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    #[inline]
    fn bucket_of(v: u64) -> usize {
        ((64 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        // lint:allow(panic-audit) bucket_of clamps to HIST_BUCKETS - 1
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Observe a duration in microseconds (the repo-wide convention for
    /// `*_us` histogram names).
    #[inline]
    pub fn observe_duration(&self, d: Duration) {
        self.observe(d.as_micros() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Per-bucket counts (Relaxed loads; best-effort consistent with
    /// `count()` under concurrent observes, exact at quiescence).
    pub fn bucket_counts(&self) -> [u64; HIST_BUCKETS] {
        let mut out = [0u64; HIST_BUCKETS];
        for (slot, b) in out.iter_mut().zip(self.buckets.iter()) {
            *slot = b.load(Ordering::Relaxed);
        }
        out
    }
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum() as f64 / c as f64
        }
    }

    /// Approximate quantile: the geometric midpoint of the bucket where
    /// the cumulative count crosses `q`.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return bucket_mid(i);
            }
        }
        bucket_mid(HIST_BUCKETS - 1)
    }

    /// Upper bound of the highest non-empty bucket (0 when empty).
    pub fn max_bound(&self) -> u64 {
        let mut hi = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            if b.load(Ordering::Relaxed) > 0 && i > 0 {
                hi = (1u64 << i).wrapping_sub(1);
            }
        }
        hi
    }

    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
    }

    /// `{count, sum, mean, p50, p95, max}` — the snapshot JSON shape
    /// documented in ROADMAP.md.
    pub fn snapshot(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("sum", Json::Num(self.sum() as f64)),
            ("mean", Json::Num(self.mean())),
            ("p50", Json::Num(self.quantile(0.5))),
            ("p95", Json::Num(self.quantile(0.95))),
            ("max", Json::Num(self.max_bound() as f64)),
        ])
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

fn bucket_mid(i: usize) -> f64 {
    if i == 0 {
        0.0
    } else {
        (1u64 << (i - 1)) as f64 * std::f64::consts::SQRT_2
    }
}

// ---- registry -------------------------------------------------------------

#[derive(Clone, Copy)]
enum Metric {
    Counter(&'static Counter),
    Gauge(&'static Gauge),
    Histogram(&'static Histogram),
}

/// Name → metric map. The `Mutex` guards only registration and
/// snapshotting; handles returned from `counter`/`gauge`/`histogram`
/// are `&'static` and never re-enter the lock.
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    pub const fn new() -> Registry {
        Registry { metrics: Mutex::new(BTreeMap::new()) }
    }

    /// Poison-proof lock: a holder that panicked can only have been
    /// mid-registration or mid-snapshot, and the map stays structurally
    /// sound either way — telemetry must never compound a panic.
    fn lock(&self) -> std::sync::MutexGuard<'_, BTreeMap<String, Metric>> {
        self.metrics.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    pub fn counter(&self, name: &str) -> &'static Counter {
        let mut m = self.lock();
        let e = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Box::leak(Box::new(Counter::new()))));
        match *e {
            Metric::Counter(c) => c,
            // lint:allow(panic-audit) kind clash is a compile-time-shape bug, not input
            _ => panic!("metric {name:?} already registered with a different type"),
        }
    }

    pub fn gauge(&self, name: &str) -> &'static Gauge {
        let mut m = self.lock();
        let e = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Box::leak(Box::new(Gauge::new()))));
        match *e {
            Metric::Gauge(g) => g,
            // lint:allow(panic-audit) kind clash is a compile-time-shape bug, not input
            _ => panic!("metric {name:?} already registered with a different type"),
        }
    }

    pub fn histogram(&self, name: &str) -> &'static Histogram {
        let mut m = self.lock();
        let e = m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Box::leak(Box::new(Histogram::new()))));
        match *e {
            Metric::Histogram(h) => h,
            // lint:allow(panic-audit) kind clash is a compile-time-shape bug, not input
            _ => panic!("metric {name:?} already registered with a different type"),
        }
    }

    /// Full snapshot as sorted JSON:
    /// `{"counters": {...}, "gauges": {...}, "histograms": {...}}`.
    pub fn snapshot(&self) -> Json {
        let m = self.lock();
        let mut counters = BTreeMap::new();
        let mut gauges = BTreeMap::new();
        let mut hists = BTreeMap::new();
        for (name, v) in m.iter() {
            match *v {
                Metric::Counter(c) => {
                    counters.insert(name.clone(), Json::Num(c.get() as f64));
                }
                Metric::Gauge(g) => {
                    gauges.insert(name.clone(), Json::Num(g.get()));
                }
                Metric::Histogram(h) => {
                    hists.insert(name.clone(), h.snapshot());
                }
            }
        }
        Json::Obj(BTreeMap::from([
            ("counters".to_string(), Json::Obj(counters)),
            ("gauges".to_string(), Json::Obj(gauges)),
            ("histograms".to_string(), Json::Obj(hists)),
        ]))
    }

    /// Zero every registered metric (tests / between-run hygiene).
    /// Handles stay valid — cells are reset, not replaced.
    pub fn reset_all(&self) {
        let m = self.lock();
        for v in m.values() {
            match *v {
                Metric::Counter(c) => c.reset(),
                Metric::Gauge(g) => g.reset(),
                Metric::Histogram(h) => h.reset(),
            }
        }
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

/// The process-global registry every macro and snapshot consumer uses.
pub fn registry() -> &'static Registry {
    static R: OnceLock<Registry> = OnceLock::new();
    R.get_or_init(Registry::new)
}

// ---- span timing ----------------------------------------------------------

/// RAII span: observes elapsed wall time (µs) into `hist` on drop.
/// Prefer `time_span!` for straight-line blocks; use this where early
/// returns or `?` would skip a manual observe.
pub struct Span {
    hist: &'static Histogram,
    start: Instant,
}

impl Span {
    pub fn new(hist: &'static Histogram) -> Span {
        Span { hist, start: Instant::now() }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        self.hist.observe_duration(self.start.elapsed());
    }
}

// ---- macros ---------------------------------------------------------------

/// `counter!("serve.jobs_total")` → `&'static Counter`, registered once
/// per call site (the `OnceLock` makes the steady state lock-free).
#[macro_export]
macro_rules! counter {
    ($name:expr) => {{
        static __HANDLE: std::sync::OnceLock<&'static $crate::util::metrics::Counter> =
            std::sync::OnceLock::new();
        *__HANDLE.get_or_init(|| $crate::util::metrics::registry().counter($name))
    }};
}

/// `gauge!("sa.best_score")` → `&'static Gauge` (see `counter!`).
#[macro_export]
macro_rules! gauge {
    ($name:expr) => {{
        static __HANDLE: std::sync::OnceLock<&'static $crate::util::metrics::Gauge> =
            std::sync::OnceLock::new();
        *__HANDLE.get_or_init(|| $crate::util::metrics::registry().gauge($name))
    }};
}

/// `histogram!("serve.queue_wait_us")` → `&'static Histogram`.
#[macro_export]
macro_rules! histogram {
    ($name:expr) => {{
        static __HANDLE: std::sync::OnceLock<&'static $crate::util::metrics::Histogram> =
            std::sync::OnceLock::new();
        *__HANDLE.get_or_init(|| $crate::util::metrics::registry().histogram($name))
    }};
}

/// Time a block into a named histogram (µs) and return its value:
/// `let out = time_span!("serve.score_us", { driver.score(...) });`
#[macro_export]
macro_rules! time_span {
    ($name:expr, $body:expr) => {{
        let __hist = $crate::histogram!($name);
        let __start = std::time::Instant::now();
        let __out = $body;
        __hist.observe_duration(__start.elapsed());
        __out
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tests that assert exact values use either a private `Registry`
    // or names unique to one test — the global registry is shared with
    // every other test in this binary.

    #[test]
    fn register_increment_snapshot_exact_json() {
        let r = Registry::new();
        let c = r.counter("t.jobs");
        c.inc();
        c.add(2);
        r.gauge("t.best").set(1.5);
        assert_eq!(
            r.snapshot().to_string(),
            r#"{"counters":{"t.jobs":3},"gauges":{"t.best":1.5},"histograms":{}}"#
        );
    }

    #[test]
    fn same_name_same_cell_across_call_sites() {
        let a = crate::counter!("metrics.test.shared");
        let b = crate::counter!("metrics.test.shared");
        a.inc();
        b.inc();
        assert!(std::ptr::eq(a, b), "registry must dedupe by name");
        assert_eq!(a.get(), 2);
    }

    #[test]
    fn histogram_buckets_quantiles_and_snapshot() {
        let r = Registry::new();
        let h = r.histogram("t.lat");
        for v in [0u64, 1, 2, 3, 1000] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 1006);
        assert!((h.mean() - 201.2).abs() < 1e-9);
        let p50 = h.quantile(0.5);
        assert!((1.0..=4.0).contains(&p50), "p50 {p50}");
        let p95 = h.quantile(0.95);
        assert!((512.0..=1024.0).contains(&p95), "p95 {p95}");
        assert!(h.max_bound() >= 1000);
        let snap = h.snapshot();
        assert_eq!(snap.req("count").as_f64(), Some(5.0));
        assert_eq!(snap.req("sum").as_f64(), Some(1006.0));
        // Quantiles are monotone in q.
        assert!(h.quantile(0.95) >= h.quantile(0.5));
    }

    #[test]
    fn histogram_zero_and_large_values() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0.0, "empty histogram");
        assert_eq!(h.max_bound(), 0);
        h.observe(0);
        assert_eq!(h.quantile(0.99), 0.0, "all-zero observations");
        h.observe(u64::MAX);
        assert_eq!(h.count(), 2);
        assert!(h.max_bound() > 1u64 << 62);
    }

    #[test]
    fn concurrent_increments_are_exact() {
        let c = crate::counter!("metrics.test.concurrent");
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 80_000);
    }

    #[test]
    fn gauge_roundtrips_f64() {
        let g = crate::gauge!("metrics.test.gauge");
        g.set(-2.25);
        assert_eq!(g.get(), -2.25);
        g.set(f64::INFINITY);
        assert_eq!(g.get(), f64::INFINITY);
    }

    #[test]
    fn time_span_records_and_returns_value() {
        let v = crate::time_span!("metrics.test.span_us", { 2 + 2 });
        assert_eq!(v, 4);
        assert_eq!(crate::histogram!("metrics.test.span_us").count(), 1);
    }

    #[test]
    fn raii_span_observes_on_drop() {
        let h = crate::histogram!("metrics.test.raii_us");
        {
            let _s = Span::new(h);
        }
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn reset_all_zeroes_but_keeps_handles() {
        let r = Registry::new();
        let c = r.counter("t.c");
        let h = r.histogram("t.h");
        c.add(5);
        h.observe(9);
        r.reset_all();
        assert_eq!(c.get(), 0);
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum(), 0);
        c.inc();
        assert_eq!(c.get(), 1, "handle stays live after reset");
    }

    #[test]
    #[should_panic(expected = "different type")]
    fn type_mismatch_panics() {
        let r = Registry::new();
        r.counter("t.x");
        r.gauge("t.x");
    }

    #[test]
    fn canon_names_are_unique_shaped_and_us_suffixed() {
        let mut seen = std::collections::BTreeSet::new();
        for (name, kind) in CANON {
            assert!(seen.insert(*name), "duplicate CANON entry {name}");
            assert!(
                name.split('.').count() >= 2
                    && name.split('.').all(|s| {
                        s == "<i>"
                            || (!s.is_empty()
                                && s.chars().all(|c| {
                                    c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'
                                }))
                    }),
                "CANON entry {name} is not layer.metric shaped"
            );
            // `_us` names are histograms or gauges of microsecond
            // quantities (e.g. the linger window) — never counters.
            if name.ends_with("_us") {
                assert_ne!(*kind, Kind::Counter, "{name}: counters do not carry units");
            }
            assert_eq!(canon_kind(name), Some(*kind));
        }
        assert_eq!(canon_kind("serve.jobs_total"), Some(Kind::Counter));
        assert_eq!(canon_kind("no.such.metric"), None);
    }

    #[test]
    fn bucket_counts_sum_to_count() {
        let h = Histogram::new();
        for v in [0u64, 1, 7, 1000, u64::MAX] {
            h.observe(v);
        }
        let counts = h.bucket_counts();
        assert_eq!(counts.iter().sum::<u64>(), h.count());
        assert_eq!(counts[0], 1, "zero lands in bucket 0");
    }
}
