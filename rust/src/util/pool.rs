//! A small scoped work-pool built on `std::thread::scope`.
//!
//! The offline environment has no `rayon`, so dataset collection (the
//! paper's "32 machines × 64 cores for three months", scaled down) uses
//! this: split a list of independent jobs across N OS threads, collect
//! results in input order. Panics in workers propagate to the caller.
//!
//! Results are written into pre-sized slots through a raw pointer: the
//! atomic cursor hands each index to exactly one worker, so writes are
//! disjoint and no per-item `Mutex` is needed (the seed implementation
//! paid a lock + unlock per item, which dominated for cheap jobs).

use crate::util::trace::{self, TraceSpan};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default: all available cores,
/// bounded to keep the interactive machine responsive.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 32)
}

/// A raw pointer that may cross thread boundaries. Safety is argued at
/// the use site: each index is claimed by exactly one worker, so writes
/// through the pointer never alias.
struct SendPtr<R>(*mut Option<R>);

impl<R> Clone for SendPtr<R> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl<R> Copy for SendPtr<R> {}
// SAFETY: the pointer targets slots owned by the caller's stack frame,
// which outlives the `thread::scope` below; disjointness of writes is
// guaranteed by the atomic cursor.
unsafe impl<R: Send> Send for SendPtr<R> {}

/// Map `f` over `items` in parallel, preserving input order.
///
/// Work-stealing is approximated with an atomic cursor: threads pull the
/// next unclaimed index, so uneven per-item costs (big matrices next to
/// small ones) still balance well.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    crate::counter!("pool.tasks_total").add(n as u64);
    // Coarse tracing: each task runs under a `pool.task` span — child
    // of the caller's ambient context when one is active (workers are
    // fresh threads, so the context is captured here by value),
    // otherwise a sampled root. The span is entered so work inside the
    // task (e.g. `sa.chain`) links into the same tree.
    let caller = trace::current();
    let traced_f = |i: usize, t: &T| {
        let span = if caller.active() {
            TraceSpan::child("pool.task", caller)
        } else {
            TraceSpan::root("pool.task")
        }
        .arg("task", i as i64);
        let _g = trace::enter(span.ctx());
        f(i, t)
    };
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.iter().enumerate().map(|(i, t)| traced_f(i, t)).collect();
    }

    let cursor = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let out_ptr = SendPtr(slots.as_mut_ptr());
    // Dispatch timestamp: each claim observes how long the task sat in
    // the (virtual) queue before a worker picked it up.
    let dispatched = std::time::Instant::now();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let ptr = out_ptr;
            let cursor = &cursor;
            let f = &traced_f;
            scope.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                crate::histogram!("pool.task_wait_us").observe_duration(dispatched.elapsed());
                let r = f(i, &items[i]);
                // SAFETY: `i` was claimed exclusively via fetch_add and
                // is < n, so this write targets a distinct in-bounds
                // slot; the scope joins all workers before `slots` is
                // read or dropped.
                unsafe {
                    *ptr.0.add(i) = Some(r);
                }
            });
        }
    });

    slots
        .into_iter()
        .map(|s| s.expect("worker produced no result"))
        .collect()
}

/// Parallel for-each without collecting results.
pub fn par_for<T, F>(items: &[T], threads: usize, f: F)
where
    T: Sync,
    F: Fn(usize, &T) + Sync,
{
    let _ = par_map(items, threads, |i, t| {
        f(i, t);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..257).collect();
        let out = par_map(&items, 8, |_, &x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let items = vec![1, 2, 3];
        assert_eq!(par_map(&items, 1, |i, &x| i + x), vec![1, 3, 5]);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u8> = vec![];
        let out: Vec<u8> = par_map(&items, 4, |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn uneven_work_balances() {
        // Items with wildly different costs still all complete.
        let items: Vec<u64> = (0..64).map(|i| if i % 7 == 0 { 200_000 } else { 10 }).collect();
        let out = par_map(&items, 8, |_, &n| (0..n).fold(0u64, |a, b| a.wrapping_add(b)));
        assert_eq!(out.len(), 64);
    }

    #[test]
    fn more_threads_than_items() {
        let items = vec![5usize, 6];
        let out = par_map(&items, 16, |_, &x| x + 1);
        assert_eq!(out, vec![6, 7]);
    }

    #[test]
    fn non_copy_results_preserved() {
        // Heap-owning results survive the raw-pointer write path.
        let items: Vec<usize> = (0..100).collect();
        let out = par_map(&items, 8, |_, &x| format!("v{x}"));
        for (i, s) in out.iter().enumerate() {
            assert_eq!(s, &format!("v{i}"));
        }
    }
}
