//! Aligned text tables and CSV writers for experiment reports.
//!
//! Every experiment regenerator (`cognate experiment <id>`) prints a
//! human-readable table to stdout and writes the same rows as CSV under
//! `results/`, so figures can be re-plotted from the CSVs.

use std::fmt::Write as _;
use std::path::Path;

#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table {:?}",
            self.title
        );
        self.rows.push(cells);
    }

    /// Format a float with sensible precision for reports.
    pub fn f(x: f64) -> String {
        if x.is_nan() {
            "-".to_string()
        } else if x == 0.0 || (x.abs() >= 0.01 && x.abs() < 100_000.0) {
            format!("{x:.3}")
        } else {
            format!("{x:.3e}")
        }
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:<width$}  ", c, width = widths[i]);
            }
            out.push('\n');
        };
        line(&mut out, &self.header);
        let total: usize = widths.iter().map(|w| w + 2).sum();
        out.push_str(&"-".repeat(total.max(4)));
        out.push('\n');
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(out, "{}", self.header.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write the CSV under `dir/<name>.csv`, creating the directory.
    pub fn save_csv(&self, dir: &Path, name: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{name}.csv")), self.to_csv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "speedup"]);
        t.row(vec!["cognate-top5".into(), Table::f(1.4712)]);
        t.row(vec!["waco+fa".into(), Table::f(1.04)]);
        let s = t.render();
        assert!(s.contains("cognate-top5"));
        assert!(s.contains("1.471"));
        // Columns aligned: both rows have the same prefix width.
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[2].find("1.471"), lines[3].find("1.040"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["has,comma".into(), "has\"quote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"has,comma\""));
        assert!(csv.contains("\"has\"\"quote\""));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn float_formats() {
        assert_eq!(Table::f(f64::NAN), "-");
        assert_eq!(Table::f(1.5), "1.500");
        assert!(Table::f(1e-9).contains('e'));
    }
}
