//! Hand-rolled CLI (no `clap` offline). Subcommands:
//!
//!   cognate gen        [--scale N]                 generate + describe the collection
//!   cognate collect    [--platform P] [--op O]     collect datasets into results/cache
//!   cognate pretrain   [--op O] [--variant V]      pre-train on CPU, save θ
//!   cognate experiment <id|all> [--scale N]        regenerate paper tables/figures
//!   cognate search     [--op O] [--target P]       tune one synthetic matrix end to end
//!   cognate serve      [--addr A] [--max-jobs N] [--shards S] [--linger-max MS]
//!                                                run the sharded auto-tuning service
//!   cognate stats      [--addr A]                 scrape a running service's metrics
//!   cognate trace      [--addr A]                 fetch a running service's span trace
//!   cognate bench-sim                              quick simulator throughput check
//!
//! Every command accepts `--metrics-out PATH` to dump the telemetry
//! snapshot at exit (written as `METRICS_<cmd>.json` when PATH is a
//! directory) and `--trace-out PATH` to drain the span rings into
//! Chrome-trace JSON at exit (`TRACE_<cmd>.json` when PATH is a
//! directory). Span sampling defaults to 1.0 for CLI runs and 0.01
//! for `serve`; `COGNATE_TRACE_SAMPLE` overrides both.

use crate::config::PlatformId;
use crate::coordinator::{experiments, Pipeline, Scale};
use crate::kernels::Op;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;

pub struct Args {
    pub cmd: String,
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

pub fn parse(argv: &[String]) -> Result<Args> {
    if argv.is_empty() {
        bail!("usage: cognate <command> [args] — see `cognate help`");
    }
    let cmd = argv[0].clone();
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 1;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(name.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Ok(Args { cmd, positional, flags })
}

impl Args {
    pub fn flag(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }
    pub fn flag_usize(&self, name: &str, default: usize) -> usize {
        self.flags
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
    /// Flag value with an environment-variable fallback (flag wins).
    pub fn flag_env(&self, name: &str, env: &str, default: &str) -> String {
        self.flags
            .get(name)
            .cloned()
            .or_else(|| std::env::var(env).ok())
            .unwrap_or_else(|| default.to_string())
    }
    pub fn flag_env_usize(&self, name: &str, env: &str, default: usize) -> usize {
        self.flag_env(name, env, "").parse().unwrap_or(default)
    }
    pub fn flag_env_f64(&self, name: &str, env: &str, default: f64) -> f64 {
        self.flag_env(name, env, "").parse().unwrap_or(default)
    }
    /// `--scale micro` is the smallest runnable shape (used by the CLI
    /// round-trip test); `--scale N` multiplies toward paper scale.
    pub fn scale(&self) -> Scale {
        if self.flags.get("scale").map(|s| s.as_str()) == Some("micro") {
            Scale::micro()
        } else {
            Scale::scaled(self.flag_usize("scale", 1))
        }
    }
    pub fn op(&self) -> Result<Op> {
        Op::parse(&self.flag("op", "spmm")).context("bad --op (spmm|sddmm)")
    }
    pub fn platform(&self, flag: &str, default: &str) -> Result<PlatformId> {
        PlatformId::parse(&self.flag(flag, default)).context("bad platform (cpu|spade|gpu)")
    }
}

pub const HELP: &str = "\
cognate — COGNATE (ICML'25) reproduction: transfer-learned cost models
for sparse tensor programs on emerging hardware.

USAGE: cognate <command> [--flags]

COMMANDS
  gen         [--scale N]                      generate + summarise the matrix collection
  pretrain    [--op O] [--variant V] [--out ckpt] [--scale N]
                                               pre-train on CPU, write a checkpoint
  finetune    --ckpt FILE [--target P] [--op O] [--out ckpt2]
                                               few-shot fine-tune a checkpoint
  eval        --ckpt FILE [--target P] [--op O] [--k K]
                                               evaluate a checkpoint (top-k speedups)
  roofline    [--block-m 1024] [--block-n 128]  TPU MXU/VMEM estimates for the L1 kernels
  collect     [--platform cpu|spade|gpu] [--op spmm|sddmm] [--scale N]
                                               collect a performance dataset (cached)
  experiment  <table1|fig2|fig4|...|all> [--scale N]
                                               regenerate a paper table/figure
  search      [--op O] [--target P] [--k K] [--scale N]
                                               tune one synthetic matrix end to end
  serve       [--addr 127.0.0.1:7199] [--target P] [--op O] [--scale N] [--max-jobs N]
              [--shards S] [--linger-max MS]
                                               run the sharded auto-tuning service
                                               (--max-jobs N stops after N jobs; 0 = forever;
                                               --shards S model replicas behind a least-loaded
                                               router; --linger-max MS caps each shard's
                                               adaptive batch-coalescing window)
  stats       [--addr 127.0.0.1:7199]          fetch a live telemetry snapshot from a
                                               running service ({\"stats\": true} request)
  trace       [--addr 127.0.0.1:7199]          fetch a live Chrome-trace span dump from a
                                               running service ({\"trace\": true} request)
  help                                         this text

GLOBAL FLAGS
  --metrics-out PATH    write the telemetry snapshot (counters / gauges /
                        histograms, sorted JSON) when the command exits;
                        if PATH is a directory, writes METRICS_<cmd>.json
  --trace-out PATH      drain the span rings into Chrome trace_event JSON
                        (Perfetto / chrome://tracing loadable) when the
                        command exits; if PATH is a directory, writes
                        TRACE_<cmd>.json
  --results-dir DIR     root for the dataset cache, training telemetry
                        (metrics_epochs.jsonl) and default outputs
                        (default: results/)
  --scale micro|N       micro = smallest runnable shape (tests);
                        N multiplies the small scale toward paper scale

ENVIRONMENT
  COGNATE_LOG           stderr verbosity: quiet|warn|info|debug (or 0-3);
                        default info
  COGNATE_ARTIFACTS     override the ./artifacts directory
  COGNATE_SHARDS        default for serve --shards
  COGNATE_LINGER_MAX    default for serve --linger-max (milliseconds)
  COGNATE_TRACE_SAMPLE  root-span sample probability in [0,1];
                        default 0.01 for serve, 1.0 for other commands

Artifacts must exist (run `make artifacts`); set COGNATE_ARTIFACTS to
override the ./artifacts directory.";

pub fn main_inner(argv: &[String]) -> Result<()> {
    let args = parse(argv)?;
    // Span sampling: a CLI run is one deliberate invocation, so trace
    // everything by default; serve handles a request stream, so sample
    // 1% unless COGNATE_TRACE_SAMPLE says otherwise.
    crate::util::trace::init_from_env(if args.cmd == "serve" { 0.01 } else { 1.0 });
    let result = dispatch(&args);
    // Snapshot even when the command failed — partial telemetry is
    // often the most useful artifact of a failed run.
    if args.flags.contains_key("metrics-out") {
        if let Err(e) = write_metrics_out(&args) {
            crate::warn!("metrics-out: {e:#}");
        }
    }
    if args.flags.contains_key("trace-out") {
        if let Err(e) = write_trace_out(&args) {
            crate::warn!("trace-out: {e:#}");
        }
    }
    result
}

fn dispatch(args: &Args) -> Result<()> {
    match args.cmd.as_str() {
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        "gen" => cmd_gen(args),
        "collect" => cmd_collect(args),
        "pretrain" => cmd_pretrain(args),
        "finetune" => cmd_finetune(args),
        "eval" => cmd_eval(args),
        "roofline" => {
            let t = crate::platform::roofline::report(
                args.flag_usize("block-m", 1024),
                args.flag_usize("block-n", 128),
            );
            println!("{}", t.render());
            Ok(())
        }
        "experiment" => cmd_experiment(args),
        "search" => cmd_search(args),
        "serve" => cmd_serve(args),
        "stats" => cmd_stats(args),
        "trace" => cmd_trace(args),
        other => bail!("unknown command {other:?} — see `cognate help`"),
    }
}

/// Pipeline at the requested scale, honouring `--results-dir` (dataset
/// cache, training telemetry, default checkpoint paths all live there).
fn pipeline_for(args: &Args) -> Result<Pipeline> {
    let mut pipe = Pipeline::new(args.scale())?;
    if let Some(dir) = args.flags.get("results-dir") {
        pipe.results_dir = std::path::PathBuf::from(dir);
    }
    Ok(pipe)
}

/// Resolve `--metrics-out` and write the registry snapshot there.
fn write_metrics_out(args: &Args) -> Result<()> {
    let raw = args.flag("metrics-out", "");
    anyhow::ensure!(!raw.is_empty() && raw != "true", "--metrics-out needs a PATH");
    let mut path = std::path::PathBuf::from(&raw);
    if path.is_dir() {
        path = path.join(format!("METRICS_{}.json", args.cmd));
    }
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let snap = crate::util::metrics::registry().snapshot();
    std::fs::write(&path, format!("{}\n", snap.to_string()))?;
    println!("wrote metrics snapshot: {}", path.display());
    Ok(())
}

/// Resolve `--trace-out` and drain the span rings there as
/// Chrome-trace JSON.
fn write_trace_out(args: &Args) -> Result<()> {
    let raw = args.flag("trace-out", "");
    anyhow::ensure!(!raw.is_empty() && raw != "true", "--trace-out needs a PATH");
    let mut path = std::path::PathBuf::from(&raw);
    if path.is_dir() {
        path = path.join(format!("TRACE_{}.json", args.cmd));
    }
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let n = crate::util::trace::write_chrome_trace(&path.to_string_lossy())?;
    println!("wrote chrome trace ({n} spans): {}", path.display());
    Ok(())
}

fn cmd_stats(args: &Args) -> Result<()> {
    let addr = args.flag("addr", "127.0.0.1:7199");
    let sock: std::net::SocketAddr =
        addr.parse().with_context(|| format!("bad --addr {addr:?}"))?;
    let snap = crate::coordinator::serve::request_stats(sock)?;
    println!("{}", snap.to_string());
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let addr = args.flag("addr", "127.0.0.1:7199");
    let sock: std::net::SocketAddr =
        addr.parse().with_context(|| format!("bad --addr {addr:?}"))?;
    let trace = crate::coordinator::serve::request_trace(sock)?;
    println!("{}", trace.to_string_pretty());
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<()> {
    let mut pipe = pipeline_for(args)?;
    let coll = pipe.collection();
    let mut t = crate::util::table::Table::new(
        "matrix collection",
        &["name", "rows", "cols", "nnz", "density"],
    );
    for info in coll.iter().take(30) {
        let m = &info.matrix;
        t.row(vec![
            info.name.clone(),
            m.rows.to_string(),
            m.cols.to_string(),
            m.nnz().to_string(),
            format!("{:.2e}", m.density()),
        ]);
    }
    println!("{}", t.render());
    println!("({} matrices total)", coll.len());
    Ok(())
}

fn cmd_collect(args: &Args) -> Result<()> {
    let mut pipe = pipeline_for(args)?;
    let platform = args.platform("platform", "spade")?;
    let op = args.op()?;
    let ds = pipe.dataset(platform, op)?;
    println!(
        "dataset {}/{}: {} matrices × {} configs",
        platform.name(),
        op.name(),
        ds.records.len(),
        ds.records.first().map(|r| r.costs.len()).unwrap_or(0)
    );
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .context("experiment id required (or `all`)")?;
    let mut pipe = pipeline_for(args)?;
    if which == "all" {
        experiments::run_all(&mut pipe)?;
    } else {
        experiments::run(&mut pipe, which)?;
    }
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    use crate::model::ModelDriver;
    use crate::platform::make_platform;
    use crate::search::{eval_one, score_all};
    use crate::sparse::gen::{generate, Family};
    use crate::train::train;

    let mut pipe = pipeline_for(args)?;
    let op = args.op()?;
    let target = args.platform("target", "spade")?;
    let k = args.flag_usize("k", 5);

    // Train the full pipeline at the current scale.
    let src = pipe.dataset(PlatformId::Cpu, op)?;
    let (src_pool, _) = pipe.splits(&src);
    let src_idx = pipe.pretrain_subset(&src, &src_pool, pipe.scale.pretrain_matrices);
    let zenc_src = pipe.trained_ae(PlatformId::Cpu, "ae", 1)?;
    let mut driver = ModelDriver::init(pipe.rt.clone(), "cognate", 3)?;
    train(&mut driver, &zenc_src, &src, &src_idx, &[], &pipe.scale.pretrain_opts.clone())?;
    let tgt = pipe.dataset(target, op)?;
    let (pool, _) = pipe.splits(&tgt);
    let ft: Vec<usize> = pool.into_iter().take(pipe.scale.finetune_matrices).collect();
    let zenc = pipe.trained_ae(target, "ae", 2)?;
    train(&mut driver, &zenc, &tgt, &ft, &[], &pipe.scale.finetune_opts.clone())?;

    // Tune a fresh matrix the model has never seen.
    let m = generate(Family::Rmat, 1200, 1200, 0.01, 0xFEED);
    let sim = make_platform(target);
    let costs = sim.eval_all(&m, op);
    let rec = crate::coordinator::serve::record_for(&m, costs, "query");
    let scores = score_all(&driver, &zenc, &tgt, &rec, None)?;
    let e = eval_one(&rec, &scores, sim.default_index(), k);
    println!(
        "matrix {}×{} nnz={} on {}/{}: top-{k} speedup {:.3}× (optimal {:.3}×), config #{}",
        m.rows,
        m.cols,
        m.nnz(),
        target.name(),
        op.name(),
        e.speedup,
        e.optimal_speedup,
        e.chosen_index
    );
    println!("chosen config: {:?}", sim.config(e.chosen_index));
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use crate::coordinator::serve::{LingerPolicy, ServeOpts};
    use crate::model::ModelDriver;
    use crate::train::train;

    let mut pipe = pipeline_for(args)?;
    let op = args.op()?;
    let target = args.platform("target", "spade")?;
    let addr = args.flag("addr", "127.0.0.1:7199");
    let max_jobs = match args.flag_usize("max-jobs", 0) {
        0 => None,
        n => Some(n),
    };
    let shards = args.flag_env_usize("shards", "COGNATE_SHARDS", 1).max(1);
    // Adaptive linger cap in milliseconds; guard the Duration
    // conversion (from_secs_f64 panics on negative / non-finite).
    let mut linger_ms = args.flag_env_f64("linger-max", "COGNATE_LINGER_MAX", 8.0);
    if !linger_ms.is_finite() || linger_ms < 0.0 {
        linger_ms = 8.0;
    }
    let opts = ServeOpts {
        shards,
        linger: LingerPolicy::adaptive_to(std::time::Duration::from_secs_f64(linger_ms / 1e3)),
        max_jobs,
        ..ServeOpts::default()
    };

    let src = pipe.dataset(PlatformId::Cpu, op)?;
    let (src_pool, _) = pipe.splits(&src);
    let src_idx = pipe.pretrain_subset(&src, &src_pool, pipe.scale.pretrain_matrices);
    let zenc_src = pipe.trained_ae(PlatformId::Cpu, "ae", 1)?;
    let mut driver = ModelDriver::init(pipe.rt.clone(), "cognate", 3)?;
    train(&mut driver, &zenc_src, &src, &src_idx, &[], &pipe.scale.pretrain_opts.clone())?;
    let tgt = pipe.dataset(target, op)?;
    let (pool, _) = pipe.splits(&tgt);
    let ft: Vec<usize> = pool.into_iter().take(pipe.scale.finetune_matrices).collect();
    let zenc = pipe.trained_ae(target, "ae", 2)?;
    train(&mut driver, &zenc, &tgt, &ft, &[], &pipe.scale.finetune_opts.clone())?;

    println!(
        "serving tuned cost model on {addr} ({shards} shard{}, linger cap {linger_ms}ms; Ctrl-C to stop)",
        if shards == 1 { "" } else { "s" }
    );
    crate::coordinator::serve::serve(driver, zenc, target, &addr, opts, |a| {
        println!("ready on {a}");
    })
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    use crate::model::checkpoint::Checkpoint;
    use crate::model::ModelDriver;
    use crate::train::train;
    let mut pipe = pipeline_for(args)?;
    let op = args.op()?;
    let variant = args.flag("variant", "cognate");
    let out = args.flag("out", "results/pretrained.ckpt");
    let ds = pipe.dataset(PlatformId::Cpu, op)?;
    let (pool, _) = pipe.splits(&ds);
    let idx = pipe.pretrain_subset(&ds, &pool, pipe.scale.pretrain_matrices);
    let zenc = pipe.trained_ae(PlatformId::Cpu, "ae", 1)?;
    let mut driver = ModelDriver::init(pipe.rt.clone(), &variant, 11)?;
    let opts = pipe.train_opts_with_telemetry(&pipe.scale.pretrain_opts);
    let logs = train(&mut driver, &zenc, &ds, &idx, &[], &opts)?;
    let note = format!(
        "pretrained variant={variant} op={} matrices={} final_loss={:.4}",
        op.name(), idx.len(), logs.last().map(|l| l.train_loss).unwrap_or(f64::NAN)
    );
    Checkpoint::from_driver(&driver, &note).save(std::path::Path::new(&out))?;
    println!("wrote {out} ({note})");
    Ok(())
}

fn cmd_finetune(args: &Args) -> Result<()> {
    use crate::model::checkpoint::Checkpoint;
    use crate::train::train;
    let mut pipe = pipeline_for(args)?;
    let op = args.op()?;
    let target = args.platform("target", "spade")?;
    let ckpt_path = args.flags.get("ckpt").context("--ckpt required")?.clone();
    let out = args.flag("out", "results/finetuned.ckpt");
    let ckpt = Checkpoint::load(std::path::Path::new(&ckpt_path))?;
    let pre = ckpt.into_driver(pipe.rt.clone())?;
    let mut driver = pre.fork_for_finetune();
    let tgt = pipe.dataset(target, op)?;
    let (pool, _) = pipe.splits(&tgt);
    let ft: Vec<usize> = pool.into_iter().take(pipe.scale.finetune_matrices).collect();
    let zenc = pipe.trained_ae(target, "ae", 2)?;
    let opts = pipe.train_opts_with_telemetry(&pipe.scale.finetune_opts);
    train(&mut driver, &zenc, &tgt, &ft, &[], &opts)?;
    let note = format!("finetuned on {} ({} matrices) from {ckpt_path}", target.name(), ft.len());
    Checkpoint::from_driver(&driver, &note).save(std::path::Path::new(&out))?;
    println!("wrote {out} ({note})");
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    use crate::model::checkpoint::Checkpoint;
    use crate::search::{evaluate, oracle_summary};
    let mut pipe = pipeline_for(args)?;
    let op = args.op()?;
    let target = args.platform("target", "spade")?;
    let k = args.flag_usize("k", 5);
    let ckpt_path = args.flags.get("ckpt").context("--ckpt required")?.clone();
    let driver = Checkpoint::load(std::path::Path::new(&ckpt_path))?.into_driver(pipe.rt.clone())?;
    let tgt = pipe.dataset(target, op)?;
    let (_, eval_idx) = pipe.splits(&tgt);
    let zenc = pipe.trained_ae(target, "ae", 2)?;
    let di = crate::config::default_config_index(target);
    let s = evaluate(&driver, &zenc, &tgt, &eval_idx, di, k)?;
    let oracle = oracle_summary(&tgt, &eval_idx, di);
    println!(
        "top-{k} geomean {:.3}x (max {:.3}x, ape {:.1}%), oracle {:.3}x — {} eval matrices",
        s.geomean_speedup, s.max_speedup, s.ape, oracle.geomean_speedup, s.per_matrix.len()
    );
    Ok(())
}
