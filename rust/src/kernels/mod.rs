//! Executable sparse kernels: numerics-identical SpMM / SDDMM under
//! configurable schedules, tested against naive oracles. These anchor
//! the analytical platform cost models and power the GNN end-to-end
//! example.
//!
//! # Schedule semantics
//!
//! Both kernels take a schedule mirroring the CPU config space: the row
//! loop is strip-mined by `i_block`, the dense-column (SpMM) or
//! reduction (SDDMM) loop by `k_block`, and `outer_k` hoists the
//! k-strip loop outside the row loop (the `[k2, i2, …]` orders of
//! §3.2). Every variant — scheduled, and parallel at any thread count —
//! honors the schedule and preserves a fixed per-element accumulation
//! order: SpMM accumulates each output element over the sparse column
//! index `j` ascending; SDDMM reduces over `k` with a shared 4-wide
//! unrolled dot kernel whose partial sums combine in a fixed order. The
//! parallel kernels are therefore bitwise identical across thread
//! counts (and, for SpMM, across schedules too).
//!
//! # nnz-balanced partitioning
//!
//! Parallel kernels split rows by *nonzero count*, not row count:
//! `nnz_balanced_partition` binary-searches the CSR `indptr` prefix
//! sums so each thread gets ≈ nnz/threads of the actual work. On
//! power-law matrices (a few very dense rows, a long sparse tail) the
//! seed's equal-row-count split left most threads idle behind the one
//! that drew the dense rows.

// Determinism guard (clippy layer of the cognate-lint `determinism`
// rule, backed by clippy.toml's disallowed lists): no hash-order
// iteration or wall-clock reads in kernel code.
#![warn(clippy::disallowed_methods, clippy::disallowed_types)]

pub mod sddmm;
pub mod spmm;

pub use sddmm::{sddmm_parallel, sddmm_ref, sddmm_scheduled, SddmmSchedule};
pub use spmm::{spmm_parallel, spmm_ref, spmm_scheduled, SpmmSchedule};

/// Row boundaries splitting a CSR matrix into `parts` contiguous row
/// ranges of approximately equal nonzero count.
///
/// `indptr` is the CSR row-pointer array (`indptr[i]` = nnz before row
/// `i`, already a prefix sum); the result has `parts + 1` entries with
/// `bounds[0] == 0` and `bounds[parts] == rows`, and range `t` is
/// `bounds[t]..bounds[t+1]`. Assignment is greedy: each part takes rows
/// until it holds its share of the *remaining* nonzeros, found by a
/// binary search (`partition_point`) over the prefix sums — so a single
/// very dense row absorbs one part without dragging the light tail
/// along (the failure mode of fixed-quantile targets). O(parts · log
/// rows); ranges may be empty when one row exceeds the per-part share.
pub fn nnz_balanced_partition(indptr: &[usize], parts: usize) -> Vec<usize> {
    let rows = indptr.len().saturating_sub(1);
    let parts = parts.max(1);
    let total = indptr.last().copied().unwrap_or(0);
    let mut bounds = Vec::with_capacity(parts + 1);
    bounds.push(0usize);
    let mut row = 0usize;
    for t in 0..parts - 1 {
        let remaining = total - indptr[row.min(rows)];
        let share = remaining.div_ceil(parts - t);
        let target = indptr[row.min(rows)] + share;
        row = indptr.partition_point(|&x| x < target).min(rows).max(row);
        bounds.push(row);
    }
    bounds.push(rows);
    // Telemetry: max-part / ideal-share load ratio (1.0 = perfectly
    // balanced; >1 means one thread carries that multiple of its share).
    if total > 0 && parts > 1 {
        let max_part = bounds
            .windows(2)
            .map(|w| indptr[w[1]] - indptr[w[0]])
            .max()
            .unwrap_or(0);
        let ideal = total as f64 / parts as f64;
        crate::gauge!("kernels.partition_imbalance").set(max_part as f64 / ideal);
    }
    bounds
}

/// Which sparse primitive a config / dataset / model targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    Spmm,
    Sddmm,
}

impl Op {
    pub fn name(&self) -> &'static str {
        match self {
            Op::Spmm => "spmm",
            Op::Sddmm => "sddmm",
        }
    }
    pub fn parse(s: &str) -> Option<Op> {
        match s {
            "spmm" => Some(Op::Spmm),
            "sddmm" => Some(Op::Sddmm),
            _ => None,
        }
    }
}

pub const ALL_OPS: [Op; 2] = [Op::Spmm, Op::Sddmm];

/// Dense feature width N (SpMM) / K (SDDMM) used throughout evaluation —
/// the paper's GNN-style setting uses a few hundred; we default to 128.
pub const DENSE_DIM: usize = 128;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_all_rows_monotone() {
        // indptr for rows with nnz [3, 0, 5, 1, 7, 0, 2, 2].
        let indptr = [0usize, 3, 3, 8, 9, 16, 16, 18, 20];
        for parts in 1..=10 {
            let b = nnz_balanced_partition(&indptr, parts);
            assert_eq!(b.len(), parts + 1);
            assert_eq!(b[0], 0);
            assert_eq!(*b.last().unwrap(), 8);
            for w in b.windows(2) {
                assert!(w[0] <= w[1]);
            }
        }
    }

    #[test]
    fn partition_balances_skewed_nnz() {
        // One dense row among many light rows: the dense row must not
        // drag its whole equal-row-count half along with it.
        let mut indptr = vec![0usize];
        let mut total = 0;
        for i in 0..100 {
            total += if i == 0 { 1000 } else { 1 };
            indptr.push(total);
        }
        let b = nnz_balanced_partition(&indptr, 4);
        // Part 0 should hold just the dense row (1000 of 1099 nnz).
        assert!(b[1] <= 2, "bounds {b:?}");
        let nnz_of = |t: usize| indptr[b[t + 1]] - indptr[b[t]];
        // Remaining parts split the light tail about evenly.
        for t in 1..4 {
            assert!(nnz_of(t) <= 60, "part {t} got {} nnz: {b:?}", nnz_of(t));
        }
    }

    #[test]
    fn partition_empty_and_degenerate() {
        assert_eq!(nnz_balanced_partition(&[0], 4), vec![0, 0, 0, 0, 0]);
        assert_eq!(nnz_balanced_partition(&[0, 0, 0], 2), vec![0, 0, 2]);
        assert_eq!(nnz_balanced_partition(&[0, 5], 3), vec![0, 1, 1, 1]);
        assert_eq!(nnz_balanced_partition(&[0, 2, 4], 1), vec![0, 2]);
    }

    #[test]
    fn partition_even_nnz_splits_evenly() {
        // 8 rows × 4 nnz each, 4 parts → 2 rows per part.
        let indptr: Vec<usize> = (0..=8).map(|i| i * 4).collect();
        assert_eq!(nnz_balanced_partition(&indptr, 4), vec![0, 2, 4, 6, 8]);
    }
}
