//! Executable sparse kernels: numerics-identical SpMM / SDDMM under
//! configurable schedules, tested against naive oracles. These anchor
//! the analytical platform cost models and power the GNN end-to-end
//! example.

pub mod sddmm;
pub mod spmm;

pub use sddmm::{sddmm_ref, sddmm_scheduled, SddmmSchedule};
pub use spmm::{spmm_parallel, spmm_ref, spmm_scheduled, SpmmSchedule};

/// Which sparse primitive a config / dataset / model targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    Spmm,
    Sddmm,
}

impl Op {
    pub fn name(&self) -> &'static str {
        match self {
            Op::Spmm => "spmm",
            Op::Sddmm => "sddmm",
        }
    }
    pub fn parse(s: &str) -> Option<Op> {
        match s {
            "spmm" => Some(Op::Spmm),
            "sddmm" => Some(Op::Sddmm),
            _ => None,
        }
    }
}

pub const ALL_OPS: [Op; 2] = [Op::Spmm, Op::Sddmm];

/// Dense feature width N (SpMM) / K (SDDMM) used throughout evaluation —
/// the paper's GNN-style setting uses a few hundred; we default to 128.
pub const DENSE_DIM: usize = 128;
