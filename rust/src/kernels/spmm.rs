//! Executable SpMM (D = A · B, A sparse CSR, B/D dense row-major).
//!
//! This is the TACO-like substrate: one numerics-identical computation
//! under several *schedules* (loop orders / strip-mining / tiling), so
//! that (a) correctness of every schedule can be checked against the
//! naive oracle and (b) wall-clock differences between schedules give a
//! sanity anchor for the CPU analytical cost model.
//!
//! Every output element `(i, k)` accumulates `v · B[j, k]` over the
//! sparse column index `j` in ascending order in *every* path — naive,
//! scheduled (both `outer_k` settings), and parallel at any thread
//! count — so all variants are bitwise identical, not just close.
//! `spmm_parallel` splits rows by nonzero count
//! (`kernels::nnz_balanced_partition`) and runs the full schedule
//! within each thread's row range.

use super::nnz_balanced_partition;
use crate::sparse::Csr;

/// Loop schedule for SpMM. Mirrors the CPU config space: the i loop
/// (rows) is strip-mined by `i_block`, the k loop (dense columns of B)
/// by `k_block`, and `outer_k` chooses whether the k-strip loop is
/// hoisted outside the row loop (the `[k2, i2, ...]` orders of §3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SpmmSchedule {
    pub i_block: usize,
    pub k_block: usize,
    pub outer_k: bool,
}

impl Default for SpmmSchedule {
    fn default() -> Self {
        Self { i_block: 64, k_block: 32, outer_k: false }
    }
}

/// Naive reference: straightforward row-major traversal. The oracle all
/// scheduled variants are tested against.
pub fn spmm_ref(a: &Csr, b: &[f32], n: usize, out: &mut [f32]) {
    assert_eq!(b.len(), a.cols * n, "B shape");
    assert_eq!(out.len(), a.rows * n, "D shape");
    out.fill(0.0);
    for i in 0..a.rows {
        let dst = &mut out[i * n..(i + 1) * n];
        for (&j, &v) in a.row_indices(i).iter().zip(a.row_values(i)) {
            let brow = &b[j as usize * n..(j as usize + 1) * n];
            for k in 0..n {
                dst[k] += v * brow[k];
            }
        }
    }
}

/// `dst[k] += v * brow[k]` over `k0..k1`, 4-wide unrolled so the
/// autovectorizer keeps lanes full. Element-wise (no reduction), so the
/// unroll cannot change any accumulation order.
#[inline]
fn axpy_strip(dst: &mut [f32], brow: &[f32], v: f32, k0: usize, k1: usize) {
    let mut k = k0;
    while k + 4 <= k1 {
        dst[k] += v * brow[k];
        dst[k + 1] += v * brow[k + 1];
        dst[k + 2] += v * brow[k + 2];
        dst[k + 3] += v * brow[k + 3];
        k += 4;
    }
    while k < k1 {
        dst[k] += v * brow[k];
        k += 1;
    }
}

/// Scheduled SpMM over the row range `r0..r1`; `out` covers exactly
/// those rows (`(r1 - r0) * n` elements). The shared core of the
/// single-thread and parallel entry points.
fn spmm_rows_scheduled(
    a: &Csr,
    b: &[f32],
    n: usize,
    s: SpmmSchedule,
    r0: usize,
    r1: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), (r1 - r0) * n);
    out.fill(0.0);
    let ib = s.i_block.max(1);
    let kb = s.k_block.max(1);
    if s.outer_k {
        // k-strips outermost: D and B columns revisited per strip; A
        // re-streamed — good when B panel exceeds cache and n is large.
        for k0 in (0..n).step_by(kb) {
            let k1 = (k0 + kb).min(n);
            for i0 in (r0..r1).step_by(ib) {
                let i1 = (i0 + ib).min(r1);
                for i in i0..i1 {
                    let dst = &mut out[(i - r0) * n..(i - r0 + 1) * n];
                    for (&j, &v) in a.row_indices(i).iter().zip(a.row_values(i)) {
                        let brow = &b[j as usize * n..(j as usize + 1) * n];
                        axpy_strip(dst, brow, v, k0, k1);
                    }
                }
            }
        }
    } else {
        for i0 in (r0..r1).step_by(ib) {
            let i1 = (i0 + ib).min(r1);
            for i in i0..i1 {
                let dst = &mut out[(i - r0) * n..(i - r0 + 1) * n];
                for (&j, &v) in a.row_indices(i).iter().zip(a.row_values(i)) {
                    let brow = &b[j as usize * n..(j as usize + 1) * n];
                    for k0 in (0..n).step_by(kb) {
                        let k1 = (k0 + kb).min(n);
                        axpy_strip(dst, brow, v, k0, k1);
                    }
                }
            }
        }
    }
}

/// Scheduled SpMM: identical numerics to the oracle (per-element
/// accumulation order is j-ascending in every schedule).
pub fn spmm_scheduled(a: &Csr, b: &[f32], n: usize, s: SpmmSchedule, out: &mut [f32]) {
    assert_eq!(b.len(), a.cols * n, "B shape");
    assert_eq!(out.len(), a.rows * n, "D shape");
    spmm_rows_scheduled(a, b, n, s, 0, a.rows, out);
}

/// Multi-threaded scheduled SpMM over nnz-balanced row ranges.
///
/// Each thread runs the full schedule on its own disjoint slice of the
/// output; row ranges come from `nnz_balanced_partition`, so power-law
/// matrices don't serialize behind the thread that drew the dense rows.
/// Output is bitwise identical to `spmm_scheduled` for every thread
/// count.
pub fn spmm_parallel(
    a: &Csr,
    b: &[f32],
    n: usize,
    s: SpmmSchedule,
    threads: usize,
    out: &mut [f32],
) {
    assert_eq!(b.len(), a.cols * n, "B shape");
    assert_eq!(out.len(), a.rows * n, "D shape");
    let threads = threads.max(1);
    if threads == 1 || a.rows == 0 {
        return spmm_rows_scheduled(a, b, n, s, 0, a.rows, out);
    }
    let bounds = nnz_balanced_partition(&a.indptr, threads);
    std::thread::scope(|scope| {
        let mut rest: &mut [f32] = out;
        for w in bounds.windows(2) {
            let (r0, r1) = (w[0], w[1]);
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut((r1 - r0) * n);
            rest = tail;
            if r1 > r0 {
                scope.spawn(move || spmm_rows_scheduled(a, b, n, s, r0, r1, chunk));
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{generate, Family};
    use crate::util::rng::Rng;

    fn dense_b(rows: usize, n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..rows * n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn ref_known_small() {
        // A = [[2, 0], [0, 3]], B = [[1, 2], [3, 4]] ⇒ D = [[2, 4], [9, 12]]
        let a = Csr::from_coo(2, 2, vec![(0, 0, 2.0), (1, 1, 3.0)]);
        let b = vec![1.0, 2.0, 3.0, 4.0];
        let mut d = vec![0.0; 4];
        spmm_ref(&a, &b, 2, &mut d);
        assert_eq!(d, vec![2.0, 4.0, 9.0, 12.0]);
    }

    #[test]
    fn schedules_match_oracle() {
        let a = generate(Family::Rmat, 200, 150, 0.03, 11);
        let n = 40;
        let b = dense_b(a.cols, n, 5);
        let mut expect = vec![0.0; a.rows * n];
        spmm_ref(&a, &b, n, &mut expect);
        for &ib in &[1usize, 7, 64, 1000] {
            for &kb in &[1usize, 8, 33, 100] {
                for &ok in &[false, true] {
                    let s = SpmmSchedule { i_block: ib, k_block: kb, outer_k: ok };
                    let mut got = vec![0.0; a.rows * n];
                    spmm_scheduled(&a, &b, n, s, &mut got);
                    assert_close(&got, &expect, 1e-5);
                }
            }
        }
    }

    #[test]
    fn parallel_matches_oracle() {
        let a = generate(Family::PowerLaw, 333, 211, 0.02, 3);
        let n = 24;
        let b = dense_b(a.cols, n, 9);
        let mut expect = vec![0.0; a.rows * n];
        spmm_ref(&a, &b, n, &mut expect);
        for &t in &[1usize, 2, 5, 8] {
            let mut got = vec![0.0; a.rows * n];
            spmm_parallel(&a, &b, n, SpmmSchedule::default(), t, &mut got);
            assert_close(&got, &expect, 1e-5);
        }
    }

    #[test]
    fn parallel_honors_schedule_both_outer_k() {
        // Regression for the seed bug where spmm_parallel dropped its
        // schedule (`let _ = s;`): the scheduled parallel path must
        // match the oracle for outer_k both ways, at several thread
        // counts and with awkward block sizes.
        let a = generate(Family::PowerLaw, 257, 190, 0.03, 17);
        let n = 33;
        let b = dense_b(a.cols, n, 4);
        let mut expect = vec![0.0; a.rows * n];
        spmm_ref(&a, &b, n, &mut expect);
        for &ok in &[false, true] {
            let s = SpmmSchedule { i_block: 7, k_block: 5, outer_k: ok };
            for &t in &[2usize, 3, 8] {
                let mut got = vec![0.0; a.rows * n];
                spmm_parallel(&a, &b, n, s, t, &mut got);
                // Bitwise: accumulation order is j-ascending everywhere.
                assert_eq!(got, expect, "outer_k={ok} threads={t}");
            }
        }
    }

    #[test]
    fn parallel_bitwise_deterministic_across_threads() {
        let a = generate(Family::PowerLaw, 500, 400, 0.02, 23);
        let n = 17;
        let b = dense_b(a.cols, n, 8);
        let s = SpmmSchedule::default();
        let mut base = vec![0.0; a.rows * n];
        spmm_parallel(&a, &b, n, s, 1, &mut base);
        for &t in &[2usize, 8] {
            let mut got = vec![0.0; a.rows * n];
            spmm_parallel(&a, &b, n, s, t, &mut got);
            assert_eq!(got, base, "threads={t}");
        }
    }

    #[test]
    fn empty_rows_ok() {
        let a = Csr::empty(5, 5);
        let b = dense_b(5, 3, 1);
        let mut d = vec![1.0; 15];
        spmm_scheduled(&a, &b, 3, SpmmSchedule::default(), &mut d);
        assert!(d.iter().all(|&x| x == 0.0));
    }
}
