//! Executable SpMM (D = A · B, A sparse CSR, B/D dense row-major).
//!
//! This is the TACO-like substrate: one numerics-identical computation
//! under several *schedules* (loop orders / strip-mining / tiling), so
//! that (a) correctness of every schedule can be checked against the
//! naive oracle and (b) wall-clock differences between schedules give a
//! sanity anchor for the CPU analytical cost model.

use crate::sparse::Csr;

/// Loop schedule for SpMM. Mirrors the CPU config space: the i loop
/// (rows) is strip-mined by `i_block`, the k loop (dense columns of B)
/// by `k_block`, and `outer_k` chooses whether the k-strip loop is
/// hoisted outside the row loop (the `[k2, i2, ...]` orders of §3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SpmmSchedule {
    pub i_block: usize,
    pub k_block: usize,
    pub outer_k: bool,
}

impl Default for SpmmSchedule {
    fn default() -> Self {
        Self { i_block: 64, k_block: 32, outer_k: false }
    }
}

/// Naive reference: straightforward row-major traversal. The oracle all
/// scheduled variants are tested against.
pub fn spmm_ref(a: &Csr, b: &[f32], n: usize, out: &mut [f32]) {
    assert_eq!(b.len(), a.cols * n, "B shape");
    assert_eq!(out.len(), a.rows * n, "D shape");
    out.fill(0.0);
    for i in 0..a.rows {
        let dst = &mut out[i * n..(i + 1) * n];
        for (&j, &v) in a.row_indices(i).iter().zip(a.row_values(i)) {
            let brow = &b[j as usize * n..(j as usize + 1) * n];
            for k in 0..n {
                dst[k] += v * brow[k];
            }
        }
    }
}

/// Scheduled SpMM: identical numerics (FP reassociation aside — we keep
/// per-element accumulation order row-major within a k-strip so results
/// match the oracle to tight tolerance).
pub fn spmm_scheduled(a: &Csr, b: &[f32], n: usize, s: SpmmSchedule, out: &mut [f32]) {
    assert_eq!(b.len(), a.cols * n, "B shape");
    assert_eq!(out.len(), a.rows * n, "D shape");
    out.fill(0.0);
    let ib = s.i_block.max(1);
    let kb = s.k_block.max(1);
    if s.outer_k {
        // k-strips outermost: D and B columns revisited per strip; A
        // re-streamed — good when B panel exceeds cache and n is large.
        for k0 in (0..n).step_by(kb) {
            let k1 = (k0 + kb).min(n);
            for i0 in (0..a.rows).step_by(ib) {
                let i1 = (i0 + ib).min(a.rows);
                for i in i0..i1 {
                    let dst = &mut out[i * n..(i + 1) * n];
                    for (&j, &v) in a.row_indices(i).iter().zip(a.row_values(i)) {
                        let brow = &b[j as usize * n..(j as usize + 1) * n];
                        for k in k0..k1 {
                            dst[k] += v * brow[k];
                        }
                    }
                }
            }
        }
    } else {
        for i0 in (0..a.rows).step_by(ib) {
            let i1 = (i0 + ib).min(a.rows);
            for i in i0..i1 {
                let dst = &mut out[i * n..(i + 1) * n];
                for (&j, &v) in a.row_indices(i).iter().zip(a.row_values(i)) {
                    let brow = &b[j as usize * n..(j as usize + 1) * n];
                    for k0 in (0..n).step_by(kb) {
                        let k1 = (k0 + kb).min(n);
                        for k in k0..k1 {
                            dst[k] += v * brow[k];
                        }
                    }
                }
            }
        }
    }
}

/// Multi-threaded scheduled SpMM over row blocks (static partition).
pub fn spmm_parallel(a: &Csr, b: &[f32], n: usize, s: SpmmSchedule, threads: usize, out: &mut [f32]) {
    assert_eq!(out.len(), a.rows * n);
    out.fill(0.0);
    let threads = threads.max(1);
    let rows_per = a.rows.div_ceil(threads);
    // Split the output into disjoint row chunks; each thread owns one.
    let chunks: Vec<(usize, &mut [f32])> = out
        .chunks_mut(rows_per * n)
        .enumerate()
        .map(|(t, c)| (t * rows_per, c))
        .collect();
    std::thread::scope(|scope| {
        for (row0, chunk) in chunks {
            scope.spawn(move || {
                let rows = chunk.len() / n;
                for i in 0..rows {
                    let gi = row0 + i;
                    let dst = &mut chunk[i * n..(i + 1) * n];
                    for (&j, &v) in a.row_indices(gi).iter().zip(a.row_values(gi)) {
                        let brow = &b[j as usize * n..(j as usize + 1) * n];
                        for k in 0..n {
                            dst[k] += v * brow[k];
                        }
                    }
                }
                let _ = s; // schedule currently only affects single-thread path
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{generate, Family};
    use crate::util::rng::Rng;

    fn dense_b(rows: usize, n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..rows * n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn ref_known_small() {
        // A = [[2, 0], [0, 3]], B = [[1, 2], [3, 4]] ⇒ D = [[2, 4], [9, 12]]
        let a = Csr::from_coo(2, 2, vec![(0, 0, 2.0), (1, 1, 3.0)]);
        let b = vec![1.0, 2.0, 3.0, 4.0];
        let mut d = vec![0.0; 4];
        spmm_ref(&a, &b, 2, &mut d);
        assert_eq!(d, vec![2.0, 4.0, 9.0, 12.0]);
    }

    #[test]
    fn schedules_match_oracle() {
        let a = generate(Family::Rmat, 200, 150, 0.03, 11);
        let n = 40;
        let b = dense_b(a.cols, n, 5);
        let mut expect = vec![0.0; a.rows * n];
        spmm_ref(&a, &b, n, &mut expect);
        for &ib in &[1usize, 7, 64, 1000] {
            for &kb in &[1usize, 8, 33, 100] {
                for &ok in &[false, true] {
                    let s = SpmmSchedule { i_block: ib, k_block: kb, outer_k: ok };
                    let mut got = vec![0.0; a.rows * n];
                    spmm_scheduled(&a, &b, n, s, &mut got);
                    assert_close(&got, &expect, 1e-5);
                }
            }
        }
    }

    #[test]
    fn parallel_matches_oracle() {
        let a = generate(Family::PowerLaw, 333, 211, 0.02, 3);
        let n = 24;
        let b = dense_b(a.cols, n, 9);
        let mut expect = vec![0.0; a.rows * n];
        spmm_ref(&a, &b, n, &mut expect);
        for &t in &[1usize, 2, 5, 8] {
            let mut got = vec![0.0; a.rows * n];
            spmm_parallel(&a, &b, n, SpmmSchedule::default(), t, &mut got);
            assert_close(&got, &expect, 1e-5);
        }
    }

    #[test]
    fn empty_rows_ok() {
        let a = Csr::empty(5, 5);
        let b = dense_b(5, 3, 1);
        let mut d = vec![1.0; 15];
        spmm_scheduled(&a, &b, 3, SpmmSchedule::default(), &mut d);
        assert!(d.iter().all(|&x| x == 0.0));
    }
}
