//! Executable SDDMM (D = A ⊙ (B · C), A sparse CSR sampling pattern,
//! B: M×K dense, C: K×N dense, D sparse with A's pattern).
//!
//! As with SpMM, one computation under several schedules, all tested
//! against the naive oracle.

use crate::sparse::Csr;

/// Loop schedule for SDDMM: the reduction over `k` (the shared dense
/// dimension) is strip-mined by `k_block`; rows by `i_block`; `outer_k`
/// hoists the k-strip loop outside the row loop (two-pass accumulation
/// into the output values).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SddmmSchedule {
    pub i_block: usize,
    pub k_block: usize,
    pub outer_k: bool,
}

impl Default for SddmmSchedule {
    fn default() -> Self {
        Self { i_block: 64, k_block: 32, outer_k: false }
    }
}

/// Naive reference. Returns the output *values* aligned with `a.indices`.
pub fn sddmm_ref(a: &Csr, b: &[f32], c: &[f32], k: usize, out: &mut [f32]) {
    let n = a.cols;
    assert_eq!(b.len(), a.rows * k, "B shape");
    assert_eq!(c.len(), k * n, "C shape");
    assert_eq!(out.len(), a.nnz(), "D nnz");
    for i in 0..a.rows {
        let brow = &b[i * k..(i + 1) * k];
        let (start, end) = (a.indptr[i], a.indptr[i + 1]);
        for (slot, (&j, &av)) in (start..end).zip(a.row_indices(i).iter().zip(a.row_values(i))) {
            let mut acc = 0f32;
            for kk in 0..k {
                acc += brow[kk] * c[kk * n + j as usize];
            }
            out[slot] = av * acc;
        }
    }
}

/// Scheduled SDDMM; numerics match the oracle (same accumulation order
/// within each k-strip; strips summed in ascending order).
pub fn sddmm_scheduled(a: &Csr, b: &[f32], c: &[f32], k: usize, s: SddmmSchedule, out: &mut [f32]) {
    let n = a.cols;
    assert_eq!(b.len(), a.rows * k);
    assert_eq!(c.len(), k * n);
    assert_eq!(out.len(), a.nnz());
    let ib = s.i_block.max(1);
    let kb = s.k_block.max(1);
    if s.outer_k {
        out.fill(0.0);
        for k0 in (0..k).step_by(kb) {
            let k1 = (k0 + kb).min(k);
            for i0 in (0..a.rows).step_by(ib) {
                let i1 = (i0 + ib).min(a.rows);
                for i in i0..i1 {
                    let brow = &b[i * k..(i + 1) * k];
                    let (start, end) = (a.indptr[i], a.indptr[i + 1]);
                    for (slot, &j) in (start..end).zip(a.row_indices(i)) {
                        let mut acc = 0f32;
                        for kk in k0..k1 {
                            acc += brow[kk] * c[kk * n + j as usize];
                        }
                        out[slot] += acc;
                    }
                }
            }
        }
        // Apply the sampling values in a final sweep.
        for (o, &av) in out.iter_mut().zip(&a.values) {
            *o *= av;
        }
    } else {
        for i0 in (0..a.rows).step_by(ib) {
            let i1 = (i0 + ib).min(a.rows);
            for i in i0..i1 {
                let brow = &b[i * k..(i + 1) * k];
                let (start, end) = (a.indptr[i], a.indptr[i + 1]);
                for (slot, (&j, &av)) in
                    (start..end).zip(a.row_indices(i).iter().zip(a.row_values(i)))
                {
                    let mut acc = 0f32;
                    for k0 in (0..k).step_by(kb) {
                        let k1 = (k0 + kb).min(k);
                        let mut part = 0f32;
                        for kk in k0..k1 {
                            part += brow[kk] * c[kk * n + j as usize];
                        }
                        acc += part;
                    }
                    out[slot] = av * acc;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{generate, Family};
    use crate::util::rng::Rng;

    fn dense(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..len).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn ref_known_small() {
        // A = [[1, 0], [0, 2]] (values), B = [[1, 2]], C = [[1], [1]]... use 2x2:
        // B = [[1,2],[3,4]], C = [[1,0],[0,1]] ⇒ BC = [[1,2],[3,4]]
        // D = A ⊙ BC = [[1·1, 0], [0, 2·4]]
        let a = Csr::from_coo(2, 2, vec![(0, 0, 1.0), (1, 1, 2.0)]);
        let b = vec![1.0, 2.0, 3.0, 4.0];
        let c = vec![1.0, 0.0, 0.0, 1.0];
        let mut out = vec![0.0; 2];
        sddmm_ref(&a, &b, &c, 2, &mut out);
        assert_eq!(out, vec![1.0, 8.0]);
    }

    #[test]
    fn schedules_match_oracle() {
        let a = generate(Family::PowerLaw, 150, 120, 0.04, 21);
        let k = 48;
        let b = dense(a.rows * k, 1);
        let c = dense(k * a.cols, 2);
        let mut expect = vec![0.0; a.nnz()];
        sddmm_ref(&a, &b, &c, k, &mut expect);
        for &ib in &[1usize, 13, 256] {
            for &kb in &[1usize, 8, 48, 64] {
                for &ok in &[false, true] {
                    let s = SddmmSchedule { i_block: ib, k_block: kb, outer_k: ok };
                    let mut got = vec![0.0; a.nnz()];
                    sddmm_scheduled(&a, &b, &c, k, s, &mut got);
                    assert_close(&got, &expect, 1e-4);
                }
            }
        }
    }

    #[test]
    fn empty_pattern() {
        let a = Csr::empty(4, 4);
        let b = dense(4 * 8, 3);
        let c = dense(8 * 4, 4);
        let mut out = vec![];
        sddmm_scheduled(&a, &b, &c, 8, SddmmSchedule::default(), &mut out);
        assert!(out.is_empty());
    }
}
