//! Executable SDDMM (D = A ⊙ (B · C), A sparse CSR sampling pattern,
//! B: M×K dense, C: K×N dense, D sparse with A's pattern).
//!
//! As with SpMM, one computation under several schedules, all tested
//! against the naive oracle. All scheduled and parallel paths share one
//! 4-wide unrolled dot kernel (`sddmm_dot`) whose partial accumulators
//! combine in a fixed order, so for a given schedule the output is
//! bitwise identical at every thread count (and tolerance-close to the
//! sequentially-accumulating oracle). `sddmm_parallel` splits rows by
//! nonzero count via `kernels::nnz_balanced_partition`.

use super::nnz_balanced_partition;
use crate::sparse::Csr;

/// Loop schedule for SDDMM: the reduction over `k` (the shared dense
/// dimension) is strip-mined by `k_block`; rows by `i_block`; `outer_k`
/// hoists the k-strip loop outside the row loop (two-pass accumulation
/// into the output values).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SddmmSchedule {
    pub i_block: usize,
    pub k_block: usize,
    pub outer_k: bool,
}

impl Default for SddmmSchedule {
    fn default() -> Self {
        Self { i_block: 64, k_block: 32, outer_k: false }
    }
}

/// Naive reference. Returns the output *values* aligned with `a.indices`.
pub fn sddmm_ref(a: &Csr, b: &[f32], c: &[f32], k: usize, out: &mut [f32]) {
    let n = a.cols;
    assert_eq!(b.len(), a.rows * k, "B shape");
    assert_eq!(c.len(), k * n, "C shape");
    assert_eq!(out.len(), a.nnz(), "D nnz");
    for i in 0..a.rows {
        let brow = &b[i * k..(i + 1) * k];
        let (start, end) = (a.indptr[i], a.indptr[i + 1]);
        for (slot, (&j, &av)) in (start..end).zip(a.row_indices(i).iter().zip(a.row_values(i))) {
            let mut acc = 0f32;
            for kk in 0..k {
                acc += brow[kk] * c[kk * n + j as usize];
            }
            out[slot] = av * acc;
        }
    }
}

/// Strided dot product `Σ brow[kk] · C[kk, j]` over `kk ∈ k0..k1`,
/// 4-wide partial accumulators summed in a fixed order
/// `(a0 + a1) + (a2 + a3)` then the scalar remainder. Shared by every
/// scheduled/parallel path, which is what makes them mutually bitwise
/// identical.
#[inline]
fn sddmm_dot(brow: &[f32], c: &[f32], n: usize, j: usize, k0: usize, k1: usize) -> f32 {
    let (mut a0, mut a1, mut a2, mut a3) = (0f32, 0f32, 0f32, 0f32);
    let mut kk = k0;
    while kk + 4 <= k1 {
        a0 += brow[kk] * c[kk * n + j];
        a1 += brow[kk + 1] * c[(kk + 1) * n + j];
        a2 += brow[kk + 2] * c[(kk + 2) * n + j];
        a3 += brow[kk + 3] * c[(kk + 3) * n + j];
        kk += 4;
    }
    let mut acc = (a0 + a1) + (a2 + a3);
    while kk < k1 {
        acc += brow[kk] * c[kk * n + j];
        kk += 1;
    }
    acc
}

/// Scheduled SDDMM over the row range `r0..r1`; `out` covers exactly
/// the nnz slots of those rows (`indptr[r1] - indptr[r0]` values). The
/// shared core of the single-thread and parallel entry points.
fn sddmm_rows_scheduled(
    a: &Csr,
    b: &[f32],
    c: &[f32],
    k: usize,
    s: SddmmSchedule,
    r0: usize,
    r1: usize,
    out: &mut [f32],
) {
    let n = a.cols;
    let base = a.indptr[r0];
    debug_assert_eq!(out.len(), a.indptr[r1] - base);
    let ib = s.i_block.max(1);
    let kb = s.k_block.max(1);
    if s.outer_k {
        out.fill(0.0);
        for k0 in (0..k).step_by(kb) {
            let k1 = (k0 + kb).min(k);
            for i0 in (r0..r1).step_by(ib) {
                let i1 = (i0 + ib).min(r1);
                for i in i0..i1 {
                    let brow = &b[i * k..(i + 1) * k];
                    let (start, end) = (a.indptr[i], a.indptr[i + 1]);
                    for (slot, &j) in (start..end).zip(a.row_indices(i)) {
                        out[slot - base] += sddmm_dot(brow, c, n, j as usize, k0, k1);
                    }
                }
            }
        }
        // Apply the sampling values in a final sweep.
        for (o, &av) in out.iter_mut().zip(&a.values[base..a.indptr[r1]]) {
            *o *= av;
        }
    } else {
        for i0 in (r0..r1).step_by(ib) {
            let i1 = (i0 + ib).min(r1);
            for i in i0..i1 {
                let brow = &b[i * k..(i + 1) * k];
                let (start, end) = (a.indptr[i], a.indptr[i + 1]);
                for (slot, (&j, &av)) in
                    (start..end).zip(a.row_indices(i).iter().zip(a.row_values(i)))
                {
                    let mut acc = 0f32;
                    for k0 in (0..k).step_by(kb) {
                        let k1 = (k0 + kb).min(k);
                        acc += sddmm_dot(brow, c, n, j as usize, k0, k1);
                    }
                    out[slot - base] = av * acc;
                }
            }
        }
    }
}

/// Scheduled SDDMM; numerics match the oracle to tight tolerance (the
/// 4-wide dot kernel reassociates the k-reduction).
pub fn sddmm_scheduled(a: &Csr, b: &[f32], c: &[f32], k: usize, s: SddmmSchedule, out: &mut [f32]) {
    assert_eq!(b.len(), a.rows * k, "B shape");
    assert_eq!(c.len(), k * a.cols, "C shape");
    assert_eq!(out.len(), a.nnz(), "D nnz");
    sddmm_rows_scheduled(a, b, c, k, s, 0, a.rows, out);
}

/// Multi-threaded scheduled SDDMM over nnz-balanced row ranges.
///
/// Output slots are partitioned exactly along the row boundaries from
/// `nnz_balanced_partition`, so threads write disjoint slices. For a
/// given schedule the result is bitwise identical to `sddmm_scheduled`
/// at every thread count.
pub fn sddmm_parallel(
    a: &Csr,
    b: &[f32],
    c: &[f32],
    k: usize,
    s: SddmmSchedule,
    threads: usize,
    out: &mut [f32],
) {
    assert_eq!(b.len(), a.rows * k, "B shape");
    assert_eq!(c.len(), k * a.cols, "C shape");
    assert_eq!(out.len(), a.nnz(), "D nnz");
    let threads = threads.max(1);
    if threads == 1 || a.rows == 0 {
        return sddmm_rows_scheduled(a, b, c, k, s, 0, a.rows, out);
    }
    let bounds = nnz_balanced_partition(&a.indptr, threads);
    std::thread::scope(|scope| {
        let mut rest: &mut [f32] = out;
        for w in bounds.windows(2) {
            let (r0, r1) = (w[0], w[1]);
            let (chunk, tail) =
                std::mem::take(&mut rest).split_at_mut(a.indptr[r1] - a.indptr[r0]);
            rest = tail;
            if r1 > r0 {
                scope.spawn(move || sddmm_rows_scheduled(a, b, c, k, s, r0, r1, chunk));
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{generate, Family};
    use crate::util::rng::Rng;

    fn dense(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..len).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn ref_known_small() {
        // A = [[1, 0], [0, 2]] (values), B = [[1, 2]], C = [[1], [1]]... use 2x2:
        // B = [[1,2],[3,4]], C = [[1,0],[0,1]] ⇒ BC = [[1,2],[3,4]]
        // D = A ⊙ BC = [[1·1, 0], [0, 2·4]]
        let a = Csr::from_coo(2, 2, vec![(0, 0, 1.0), (1, 1, 2.0)]);
        let b = vec![1.0, 2.0, 3.0, 4.0];
        let c = vec![1.0, 0.0, 0.0, 1.0];
        let mut out = vec![0.0; 2];
        sddmm_ref(&a, &b, &c, 2, &mut out);
        assert_eq!(out, vec![1.0, 8.0]);
    }

    #[test]
    fn schedules_match_oracle() {
        let a = generate(Family::PowerLaw, 150, 120, 0.04, 21);
        let k = 48;
        let b = dense(a.rows * k, 1);
        let c = dense(k * a.cols, 2);
        let mut expect = vec![0.0; a.nnz()];
        sddmm_ref(&a, &b, &c, k, &mut expect);
        for &ib in &[1usize, 13, 256] {
            for &kb in &[1usize, 8, 48, 64] {
                for &ok in &[false, true] {
                    let s = SddmmSchedule { i_block: ib, k_block: kb, outer_k: ok };
                    let mut got = vec![0.0; a.nnz()];
                    sddmm_scheduled(&a, &b, &c, k, s, &mut got);
                    assert_close(&got, &expect, 1e-4);
                }
            }
        }
    }

    #[test]
    fn parallel_matches_oracle() {
        let a = generate(Family::PowerLaw, 220, 170, 0.03, 31);
        let k = 40;
        let b = dense(a.rows * k, 6);
        let c = dense(k * a.cols, 7);
        let mut expect = vec![0.0; a.nnz()];
        sddmm_ref(&a, &b, &c, k, &mut expect);
        for &ok in &[false, true] {
            let s = SddmmSchedule { i_block: 9, k_block: 11, outer_k: ok };
            for &t in &[1usize, 2, 5, 8] {
                let mut got = vec![0.0; a.nnz()];
                sddmm_parallel(&a, &b, &c, k, s, t, &mut got);
                assert_close(&got, &expect, 1e-4);
            }
        }
    }

    #[test]
    fn parallel_bitwise_deterministic_across_threads() {
        let a = generate(Family::PowerLaw, 400, 300, 0.02, 13);
        let k = 37;
        let b = dense(a.rows * k, 8);
        let c = dense(k * a.cols, 9);
        let s = SddmmSchedule::default();
        let mut base = vec![0.0; a.nnz()];
        sddmm_parallel(&a, &b, &c, k, s, 1, &mut base);
        for &t in &[2usize, 8] {
            let mut got = vec![0.0; a.nnz()];
            sddmm_parallel(&a, &b, &c, k, s, t, &mut got);
            assert_eq!(got, base, "threads={t}");
        }
    }

    #[test]
    fn empty_pattern() {
        let a = Csr::empty(4, 4);
        let b = dense(4 * 8, 3);
        let c = dense(8 * 4, 4);
        let mut out = vec![];
        sddmm_scheduled(&a, &b, &c, 8, SddmmSchedule::default(), &mut out);
        assert!(out.is_empty());
        let mut out2 = vec![];
        sddmm_parallel(&a, &b, &c, 8, SddmmSchedule::default(), 4, &mut out2);
        assert!(out2.is_empty());
    }
}
