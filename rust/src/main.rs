//! `cognate` CLI entrypoint — see `cognate help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = cognate::cli::main_inner(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
