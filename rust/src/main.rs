//! `cognate` CLI entrypoint — see `cognate help`.

fn main() {
    // COGNATE_LOG=quiet|warn|info|debug (or 0-3) sets stderr verbosity.
    cognate::util::logger::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = cognate::cli::main_inner(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
