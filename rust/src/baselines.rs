//! Baseline pipelines the paper compares against (Figs 2 & 4):
//!
//! * **zero-shot** — the CPU-pre-trained model applied to the target
//!   with no fine-tuning;
//! * **no-transfer** — a fresh model trained only on the (few) target
//!   samples the fine-tuning budget allows;
//! * **WACO+FA / WACO+FM** — WacoNet with feature augmentation /
//!   feature mapping, pre-trained and fine-tuned like COGNATE.
//!
//! Each returns the same `EvalSummary`, so experiment code treats all
//! methods uniformly.

use crate::dataset::Dataset;
use crate::model::ModelDriver;
use crate::runtime::Runtime;
use crate::search::{evaluate, EvalSummary};
use crate::train::{train, TrainOpts, ZEncoder};
use anyhow::Result;
use std::sync::Arc;

/// Everything a method needs to produce an EvalSummary.
pub struct MethodCtx<'a> {
    pub rt: Arc<Runtime>,
    /// Source-platform dataset (CPU) and its training matrices.
    pub source_ds: &'a Dataset,
    pub source_train_idx: &'a [usize],
    /// Target-platform dataset, its few-shot matrices and eval split.
    pub target_ds: &'a Dataset,
    pub finetune_idx: &'a [usize],
    pub eval_idx: &'a [usize],
    pub default_index: usize,
    pub pretrain_opts: TrainOpts,
    pub finetune_opts: TrainOpts,
    pub seed: i32,
}

/// Pre-train a variant on the source platform. Returns the driver so
/// several methods can share one pre-training run.
pub fn pretrain_source(
    ctx: &MethodCtx,
    variant: &str,
    zenc: &ZEncoder,
) -> Result<ModelDriver> {
    let mut driver = ModelDriver::init(ctx.rt.clone(), variant, ctx.seed)?;
    let val: Vec<usize> = Vec::new();
    train(&mut driver, zenc, ctx.source_ds, ctx.source_train_idx, &val, &ctx.pretrain_opts)?;
    Ok(driver)
}

/// Fine-tune a pre-trained driver on the target and evaluate top-k.
pub fn finetune_and_eval(
    ctx: &MethodCtx,
    pre: &ModelDriver,
    zenc: &ZEncoder,
    k: usize,
) -> Result<EvalSummary> {
    let mut driver = pre.fork_for_finetune();
    let val: Vec<usize> = Vec::new();
    train(&mut driver, zenc, ctx.target_ds, ctx.finetune_idx, &val, &ctx.finetune_opts)?;
    evaluate(&driver, zenc, ctx.target_ds, ctx.eval_idx, ctx.default_index, k)
}

/// Zero-shot: apply the source-trained model directly to the target.
pub fn zero_shot(ctx: &MethodCtx, pre: &ModelDriver, zenc: &ZEncoder, k: usize) -> Result<EvalSummary> {
    evaluate(pre, zenc, ctx.target_ds, ctx.eval_idx, ctx.default_index, k)
}

/// No-transfer: train from scratch on the fine-tuning matrices only.
pub fn no_transfer(
    ctx: &MethodCtx,
    variant: &str,
    zenc: &ZEncoder,
    k: usize,
) -> Result<EvalSummary> {
    let mut driver = ModelDriver::init(ctx.rt.clone(), variant, ctx.seed + 17)?;
    let val: Vec<usize> = Vec::new();
    // Same number of optimisation steps as pretrain+finetune would give
    // the transfer models on this data volume.
    let mut opts = ctx.finetune_opts.clone();
    opts.epochs = ctx.finetune_opts.epochs + ctx.pretrain_opts.epochs / 2;
    train(&mut driver, zenc, ctx.target_ds, ctx.finetune_idx, &val, &opts)?;
    evaluate(&driver, zenc, ctx.target_ds, ctx.eval_idx, ctx.default_index, k)
}
