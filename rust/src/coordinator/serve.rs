//! Auto-tuning service: a threaded TCP server that accepts sparse
//! matrices and replies with the predicted-best program configurations
//! for a target platform — the "cost model as a service" deployment of
//! the paper's artifact, structured like an inference router:
//!
//!   acceptor threads → bounded job queue → ONE batcher thread that
//!   coalesces up to FEAT_B featurizations per PJRT call (dynamic
//!   batching with a small linger window) → per-job top-k scoring →
//!   reply channels.
//!
//! Protocol: one JSON object per line.
//!   request:  {"id": 1, "k": 5, "rows": R, "cols": C,
//!              "coo": [[r, c, v], ...]}
//!   response: {"id": 1, "top": [cfg_idx, ...], "scores": [...],
//!              "latency_ms": ..., "batched_with": n,
//!              "stages": {"queue_wait_ms": ..., "featurize_ms": ...,
//!                         "score_ms": ...}}
//!   control:  {"stats": true} → a full `util::metrics` snapshot
//!             (answered by the connection handler, never queued), so
//!             operators can scrape the live service.
//!
//! Telemetry (canonical names in ROADMAP.md "Telemetry"): every job
//! dequeued by the batcher bumps `serve.jobs_total` and observes
//! `serve.queue_wait_us` exactly once, so `queue_wait_us.count ==
//! jobs_total` whenever the service is quiescent. Error replies of any
//! kind bump `serve.errors_total`.

use crate::dataset::MatrixRecord;
use crate::model::ModelDriver;
use crate::search::top_k;
use crate::sparse::features::density_map;
use crate::sparse::Csr;
use crate::train::{config_features, ZEncoder};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

pub struct Job {
    pub id: i64,
    pub k: usize,
    pub matrix: Csr,
    pub reply: mpsc::Sender<Json>,
    pub arrived: Instant,
}

/// Linger window for batch coalescing.
pub const LINGER: Duration = Duration::from_millis(8);

/// Run the service until `max_jobs` *jobs* have been served (`None` =
/// forever). Both the batcher and the accept loop key off the same job
/// count: when the batcher exhausts the budget it raises a shutdown
/// flag and wakes the acceptor, so a single connection sending many
/// requests consumes the budget exactly like many connections sending
/// one each. (The seed counted accepted *connections* against
/// `max_jobs`, which stopped new connections early while the batcher
/// kept serving.) A batch in flight is always completed, so slightly
/// more than `max_jobs` jobs may be answered when the last batch
/// coalesced past the budget.
///
/// Returns the bound address via the callback before serving.
pub fn serve(
    driver: ModelDriver,
    zenc: ZEncoder,
    platform: crate::config::PlatformId,
    addr: &str,
    max_jobs: Option<usize>,
    on_ready: impl FnOnce(std::net::SocketAddr) + Send + 'static,
) -> Result<()> {
    let listener = TcpListener::bind(addr).context("bind")?;
    let local = listener.local_addr()?;
    let (tx, rx) = mpsc::channel::<Job>();
    let done = Arc::new(AtomicBool::new(false));

    // Batcher thread: the only owner of the model driver, and the only
    // counter of served jobs. When it exits (budget reached or channel
    // closed) it flags shutdown and pokes the listener awake.
    let batcher = {
        let done = done.clone();
        std::thread::spawn(move || {
            batcher_loop(driver, zenc, platform, rx, max_jobs);
            done.store(true, Ordering::Release);
            let _ = TcpStream::connect(local);
        })
    };
    on_ready(local);

    // Acceptor: one handler thread per connection (connections are few;
    // the expensive resource — the model — is behind the queue anyway).
    for stream in listener.incoming() {
        if done.load(Ordering::Acquire) {
            break;
        }
        let Ok(stream) = stream else { continue };
        crate::counter!("serve.connections_total").inc();
        let tx = tx.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(stream, tx);
        });
    }
    drop(tx);
    let _ = batcher.join();
    Ok(())
}

fn handle_conn(stream: TcpStream, tx: mpsc::Sender<Job>) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let req = match Json::parse(&line) {
            Ok(r) => r,
            Err(e) => {
                crate::counter!("serve.errors_total").inc();
                let err = Json::obj(vec![("error", Json::Str(format!("bad request: {e}")))]);
                writeln!(writer, "{}", err.to_string())?;
                continue;
            }
        };
        // Control request: live metrics snapshot, answered here so it
        // works even while the scoring queue is saturated (and after
        // the job budget is spent, as long as the acceptor is up).
        if req.get("stats").and_then(|v| v.as_bool()) == Some(true) {
            crate::counter!("serve.stats_requests_total").inc();
            writeln!(
                writer,
                "{}",
                crate::util::metrics::registry().snapshot().to_string()
            )?;
            continue;
        }
        match parse_request(&req) {
            Ok((id, k, matrix)) => {
                let (rtx, rrx) = mpsc::channel();
                let job = Job { id, k, matrix, reply: rtx, arrived: Instant::now() };
                if tx.send(job).is_err() {
                    // Batcher already shut down (job budget exhausted):
                    // still reply with well-formed JSON.
                    crate::counter!("serve.errors_total").inc();
                    let err =
                        Json::obj(vec![("error", Json::Str("service shutting down".into()))]);
                    writeln!(writer, "{}", err.to_string())?;
                    continue;
                }
                let resp = rrx.recv().unwrap_or_else(|_| {
                    crate::counter!("serve.errors_total").inc();
                    Json::obj(vec![("error", Json::Str("batcher died".into()))])
                });
                writeln!(writer, "{}", resp.to_string())?;
            }
            Err(e) => {
                crate::counter!("serve.errors_total").inc();
                let err = Json::obj(vec![("error", Json::Str(e.to_string()))]);
                writeln!(writer, "{}", err.to_string())?;
            }
        }
    }
    crate::debug!("connection from {peer:?} closed");
    Ok(())
}

/// Parse a scoring request. Never panics on malformed input — every
/// missing/ill-typed field becomes an `Err` that the handler turns into
/// an `{"error": ...}` reply.
fn parse_request(req: &Json) -> Result<(i64, usize, Csr)> {
    let id = req.get("id").and_then(|v| v.as_i64()).unwrap_or(0);
    let k = req.get("k").and_then(|v| v.as_usize()).unwrap_or(5);
    let rows = req
        .get("rows")
        .and_then(|v| v.as_usize())
        .context("missing or invalid \"rows\"")?;
    let cols = req
        .get("cols")
        .and_then(|v| v.as_usize())
        .context("missing or invalid \"cols\"")?;
    let coo_json = req
        .get("coo")
        .and_then(|v| v.as_arr())
        .context("missing or invalid \"coo\"")?;
    let mut coo = Vec::with_capacity(coo_json.len());
    for e in coo_json {
        let t = e.as_arr().context("coo entry")?;
        anyhow::ensure!(t.len() >= 2, "coo entry needs [r, c] or [r, c, v]");
        let r = t[0].as_usize().context("r")? as u32;
        let c = t[1].as_usize().context("c")? as u32;
        let v = t.get(2).and_then(|x| x.as_f64()).unwrap_or(1.0) as f32;
        anyhow::ensure!((r as usize) < rows && (c as usize) < cols, "coo out of bounds");
        coo.push((r, c, v));
    }
    Ok((id, k, Csr::from_coo(rows, cols, coo)))
}

fn batcher_loop(
    driver: ModelDriver,
    zenc: ZEncoder,
    platform: crate::config::PlatformId,
    rx: mpsc::Receiver<Job>,
    max_jobs: Option<usize>,
) {
    let rt = driver.runtime().clone();
    let (het_dim, latent_dim) = (rt.dim("HET_DIM"), rt.dim("LATENT_DIM"));
    let feat_b = driver.feat_b();
    let mut served = 0usize;
    // het → z is matrix-independent: encode once up front.
    let feats0 = config_features(platform, 4096);
    let z_all = match zenc.encode(&feats0.het, het_dim, latent_dim) {
        Ok(z) => z,
        Err(e) => {
            crate::warn!("batcher: z encoding failed: {e}");
            return;
        }
    };

    while let Ok(first) = rx.recv() {
        // Dynamic batching: collect more jobs within the linger window,
        // up to the featurizer batch width.
        let mut batch = vec![first];
        let deadline = Instant::now() + LINGER;
        while batch.len() < feat_b {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(job) => batch.push(job),
                Err(_) => break,
            }
        }
        let n_batched = batch.len();
        let dequeued = Instant::now();
        crate::histogram!("serve.batch_size").observe(n_batched as u64);
        // One queue-wait observation and one jobs_total bump per job —
        // adjacent so the stats invariant has no wide race window.
        for job in &batch {
            crate::histogram!("serve.queue_wait_us")
                .observe_duration(dequeued.duration_since(job.arrived));
            crate::counter!("serve.jobs_total").inc();
        }
        let dmaps: Vec<Vec<f32>> = batch.iter().map(|j| density_map(&j.matrix)).collect();
        let dmap_refs: Vec<&[f32]> = dmaps.iter().map(|d| d.as_slice()).collect();
        let t_feat = Instant::now();
        let featurized = driver.featurize(&dmap_refs);
        let feat_elapsed = t_feat.elapsed();
        crate::histogram!("serve.featurize_us").observe_duration(feat_elapsed);
        let embeds = match featurized {
            Ok(e) => e,
            Err(e) => {
                for job in &batch {
                    crate::counter!("serve.errors_total").inc();
                    let _ = job.reply.send(Json::obj(vec![(
                        "error",
                        Json::Str(format!("featurize: {e}")),
                    )]));
                }
                served += batch.len();
                if matches!(max_jobs, Some(m) if served >= m) {
                    break;
                }
                continue;
            }
        };
        // featurize_ms is shared across the batch (one PJRT call).
        let featurize_ms = feat_elapsed.as_secs_f64() * 1e3;
        for (job, embed) in batch.into_iter().zip(embeds) {
            let queue_wait_ms =
                dequeued.duration_since(job.arrived).as_secs_f64() * 1e3;
            let feats = config_features(platform, job.matrix.cols);
            let (cfg, _) = feats.cfg_for_variant(&driver.variant);
            let t_score = Instant::now();
            let scored = driver.score_configs(&embed, cfg, &z_all);
            let score_elapsed = t_score.elapsed();
            crate::histogram!("serve.score_us").observe_duration(score_elapsed);
            let resp = match scored {
                Ok(scores) => {
                    let top = top_k(&scores, job.k);
                    Json::obj(vec![
                        ("id", Json::Num(job.id as f64)),
                        ("top", Json::arr_usize(&top)),
                        (
                            "scores",
                            Json::arr_f64(&top.iter().map(|&i| scores[i]).collect::<Vec<_>>()),
                        ),
                        (
                            "latency_ms",
                            Json::Num(job.arrived.elapsed().as_secs_f64() * 1e3),
                        ),
                        ("batched_with", Json::Num(n_batched as f64)),
                        (
                            "stages",
                            Json::obj(vec![
                                ("queue_wait_ms", Json::Num(queue_wait_ms)),
                                ("featurize_ms", Json::Num(featurize_ms)),
                                (
                                    "score_ms",
                                    Json::Num(score_elapsed.as_secs_f64() * 1e3),
                                ),
                            ]),
                        ),
                    ])
                }
                Err(e) => {
                    crate::counter!("serve.errors_total").inc();
                    Json::obj(vec![("error", Json::Str(format!("score: {e}")))])
                }
            };
            let _ = job.reply.send(resp);
            served += 1;
        }
        if let Some(m) = max_jobs {
            if served >= m {
                break;
            }
        }
    }
}

/// Blocking client helper (used by tests and the quickstart example).
pub fn request(addr: std::net::SocketAddr, id: i64, k: usize, m: &Csr) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    let mut coo = Vec::new();
    for r in 0..m.rows {
        for (&c, &v) in m.row_indices(r).iter().zip(m.row_values(r)) {
            coo.push(Json::Arr(vec![
                Json::Num(r as f64),
                Json::Num(c as f64),
                Json::Num(v as f64),
            ]));
        }
    }
    let req = Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("k", Json::Num(k as f64)),
        ("rows", Json::Num(m.rows as f64)),
        ("cols", Json::Num(m.cols as f64)),
        ("coo", Json::Arr(coo)),
    ]);
    writeln!(stream, "{}", req.to_string())?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
}

/// Fetch a live telemetry snapshot from a running service via the
/// `{"stats": true}` control request.
pub fn request_stats(addr: std::net::SocketAddr) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{}", Json::obj(vec![("stats", Json::Bool(true))]).to_string())?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Json::parse(&line).map_err(|e| anyhow::anyhow!("bad stats response: {e}"))
}

/// Turn a request matrix into the record shape used by offline eval —
/// handy for tests comparing online vs offline answers.
pub fn record_for(m: &Csr, costs: Vec<f64>, name: &str) -> MatrixRecord {
    MatrixRecord {
        name: name.to_string(),
        dmap: density_map(m),
        cols: m.cols,
        rows: m.rows,
        nnz: m.nnz(),
        costs,
    }
}
