//! Auto-tuning service: a threaded TCP server that accepts sparse
//! matrices and replies with the predicted-best program configurations
//! for a target platform — the "cost model as a service" deployment of
//! the paper's artifact, structured like an inference router:
//!
//!   acceptor threads → bounded job queue → ONE batcher thread that
//!   coalesces up to FEAT_B featurizations per PJRT call (dynamic
//!   batching with a small linger window) → per-job top-k scoring →
//!   reply channels.
//!
//! Protocol: one JSON object per line.
//!   request:  {"id": 1, "k": 5, "rows": R, "cols": C,
//!              "coo": [[r, c, v], ...]}
//!   response: {"id": 1, "top": [cfg_idx, ...], "scores": [...],
//!              "latency_ms": ..., "batched_with": n}

use crate::dataset::MatrixRecord;
use crate::model::ModelDriver;
use crate::search::top_k;
use crate::sparse::features::density_map;
use crate::sparse::Csr;
use crate::train::{config_features, ZEncoder};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::{Duration, Instant};

pub struct Job {
    pub id: i64,
    pub k: usize,
    pub matrix: Csr,
    pub reply: mpsc::Sender<Json>,
    pub arrived: Instant,
}

/// Linger window for batch coalescing.
pub const LINGER: Duration = Duration::from_millis(8);

/// Run the service until `shutdown` jobs have been served (`None` = forever).
/// Returns the bound address via the callback before serving.
pub fn serve(
    driver: ModelDriver,
    zenc: ZEncoder,
    platform: crate::config::PlatformId,
    addr: &str,
    max_jobs: Option<usize>,
    on_ready: impl FnOnce(std::net::SocketAddr) + Send + 'static,
) -> Result<()> {
    let listener = TcpListener::bind(addr).context("bind")?;
    let local = listener.local_addr()?;
    let (tx, rx) = mpsc::channel::<Job>();

    // Batcher thread: the only owner of the model driver.
    let batcher = std::thread::spawn(move || batcher_loop(driver, zenc, platform, rx, max_jobs));
    on_ready(local);

    // Acceptor: one handler thread per connection (connections are few;
    // the expensive resource — the model — is behind the queue anyway).
    let mut served = 0usize;
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let tx = tx.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(stream, tx);
        });
        served += 1;
        if let Some(m) = max_jobs {
            if served >= m {
                break;
            }
        }
    }
    drop(tx);
    let _ = batcher.join();
    Ok(())
}

fn handle_conn(stream: TcpStream, tx: mpsc::Sender<Job>) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let req = match Json::parse(&line) {
            Ok(r) => r,
            Err(e) => {
                let err = Json::obj(vec![("error", Json::Str(format!("bad request: {e}")))]);
                writeln!(writer, "{}", err.to_string())?;
                continue;
            }
        };
        match parse_request(&req) {
            Ok((id, k, matrix)) => {
                let (rtx, rrx) = mpsc::channel();
                tx.send(Job { id, k, matrix, reply: rtx, arrived: Instant::now() })
                    .map_err(|_| anyhow::anyhow!("service shut down"))?;
                let resp = rrx.recv().unwrap_or_else(|_| {
                    Json::obj(vec![("error", Json::Str("batcher died".into()))])
                });
                writeln!(writer, "{}", resp.to_string())?;
            }
            Err(e) => {
                let err = Json::obj(vec![("error", Json::Str(e.to_string()))]);
                writeln!(writer, "{}", err.to_string())?;
            }
        }
    }
    crate::debug!("connection from {peer:?} closed");
    Ok(())
}

fn parse_request(req: &Json) -> Result<(i64, usize, Csr)> {
    let id = req.get("id").and_then(|v| v.as_i64()).unwrap_or(0);
    let k = req.get("k").and_then(|v| v.as_usize()).unwrap_or(5);
    let rows = req.req("rows").as_usize().context("rows")?;
    let cols = req.req("cols").as_usize().context("cols")?;
    let coo_json = req.req("coo").as_arr().context("coo")?;
    let mut coo = Vec::with_capacity(coo_json.len());
    for e in coo_json {
        let t = e.as_arr().context("coo entry")?;
        anyhow::ensure!(t.len() >= 2, "coo entry needs [r, c] or [r, c, v]");
        let r = t[0].as_usize().context("r")? as u32;
        let c = t[1].as_usize().context("c")? as u32;
        let v = t.get(2).and_then(|x| x.as_f64()).unwrap_or(1.0) as f32;
        anyhow::ensure!((r as usize) < rows && (c as usize) < cols, "coo out of bounds");
        coo.push((r, c, v));
    }
    Ok((id, k, Csr::from_coo(rows, cols, coo)))
}

fn batcher_loop(
    driver: ModelDriver,
    zenc: ZEncoder,
    platform: crate::config::PlatformId,
    rx: mpsc::Receiver<Job>,
    max_jobs: Option<usize>,
) {
    let rt = driver.runtime().clone();
    let (het_dim, latent_dim) = (rt.dim("HET_DIM"), rt.dim("LATENT_DIM"));
    let feat_b = driver.feat_b();
    let mut served = 0usize;
    // het → z is matrix-independent: encode once up front.
    let feats0 = config_features(platform, 4096);
    let z_all = match zenc.encode(&feats0.het, het_dim, latent_dim) {
        Ok(z) => z,
        Err(e) => {
            crate::warn!("batcher: z encoding failed: {e}");
            return;
        }
    };

    while let Ok(first) = rx.recv() {
        // Dynamic batching: collect more jobs within the linger window,
        // up to the featurizer batch width.
        let mut batch = vec![first];
        let deadline = Instant::now() + LINGER;
        while batch.len() < feat_b {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(job) => batch.push(job),
                Err(_) => break,
            }
        }
        let n_batched = batch.len();
        let dmaps: Vec<Vec<f32>> = batch.iter().map(|j| density_map(&j.matrix)).collect();
        let dmap_refs: Vec<&[f32]> = dmaps.iter().map(|d| d.as_slice()).collect();
        let embeds = match driver.featurize(&dmap_refs) {
            Ok(e) => e,
            Err(e) => {
                for job in &batch {
                    let _ = job.reply.send(Json::obj(vec![(
                        "error",
                        Json::Str(format!("featurize: {e}")),
                    )]));
                }
                continue;
            }
        };
        for (job, embed) in batch.into_iter().zip(embeds) {
            let feats = config_features(platform, job.matrix.cols);
            let (cfg, _) = feats.cfg_for_variant(&driver.variant);
            let resp = match driver.score_configs(&embed, cfg, &z_all) {
                Ok(scores) => {
                    let top = top_k(&scores, job.k);
                    Json::obj(vec![
                        ("id", Json::Num(job.id as f64)),
                        ("top", Json::arr_usize(&top)),
                        (
                            "scores",
                            Json::arr_f64(&top.iter().map(|&i| scores[i]).collect::<Vec<_>>()),
                        ),
                        (
                            "latency_ms",
                            Json::Num(job.arrived.elapsed().as_secs_f64() * 1e3),
                        ),
                        ("batched_with", Json::Num(n_batched as f64)),
                    ])
                }
                Err(e) => Json::obj(vec![("error", Json::Str(format!("score: {e}")))]),
            };
            let _ = job.reply.send(resp);
            served += 1;
        }
        if let Some(m) = max_jobs {
            if served >= m {
                break;
            }
        }
    }
}

/// Blocking client helper (used by tests and the quickstart example).
pub fn request(addr: std::net::SocketAddr, id: i64, k: usize, m: &Csr) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    let mut coo = Vec::new();
    for r in 0..m.rows {
        for (&c, &v) in m.row_indices(r).iter().zip(m.row_values(r)) {
            coo.push(Json::Arr(vec![
                Json::Num(r as f64),
                Json::Num(c as f64),
                Json::Num(v as f64),
            ]));
        }
    }
    let req = Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("k", Json::Num(k as f64)),
        ("rows", Json::Num(m.rows as f64)),
        ("cols", Json::Num(m.cols as f64)),
        ("coo", Json::Arr(coo)),
    ]);
    writeln!(stream, "{}", req.to_string())?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
}

/// Turn a request matrix into the record shape used by offline eval —
/// handy for tests comparing online vs offline answers.
pub fn record_for(m: &Csr, costs: Vec<f64>, name: &str) -> MatrixRecord {
    MatrixRecord {
        name: name.to_string(),
        dmap: density_map(m),
        cols: m.cols,
        rows: m.rows,
        nnz: m.nnz(),
        costs,
    }
}
