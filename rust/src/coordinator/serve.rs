//! Auto-tuning service: a threaded TCP server that accepts sparse
//! matrices and replies with the predicted-best program configurations
//! for a target platform — the "cost model as a service" deployment of
//! the paper's artifact, structured like an inference router:
//!
//!   acceptor threads → least-loaded router → N shard batchers, each
//!   owning a `ModelDriver` replica and a bounded queue, each
//!   coalescing up to FEAT_B featurizations per PJRT call (dynamic
//!   batching with a per-shard adaptive linger window) → per-job top-k
//!   scoring → reply channels.
//!
//! Routing: the router sorts shards by queue depth (queued + in-flight
//! jobs) and `try_send`s in that order, so one slow featurize call no
//! longer stalls every connection; if every bounded queue is full it
//! blocks on the least-loaded shard rather than shedding load.
//!
//! Lingering: instead of the fixed `LINGER`, each shard runs an
//! `AdaptiveLinger` controller — shrink the window when batches fill
//! before the deadline (lingering is then pure added latency), grow it
//! toward a cap when batches run near-empty while jobs stack up behind
//! the shard (a wider window amortises the PJRT call), shrink when
//! near-empty and idle (don't hold lone jobs hostage).
//!
//! Protocol: one JSON object per line.
//!   request:  {"id": 1, "k": 5, "rows": R, "cols": C,
//!              "coo": [[r, c, v], ...], "trace_id": "00ab..."(opt)}
//!   response: {"id": 1, "top": [cfg_idx, ...], "scores": [...],
//!              "latency_ms": ..., "batched_with": n, "shard": s,
//!              "stages": {"queue_wait_ms": ..., "featurize_ms": ...,
//!                         "score_ms": ...}, "trace_id": "00ab..."(opt)}
//!   control:  {"stats": true} → a full `util::metrics` snapshot
//!             (answered by the connection handler, never queued), so
//!             operators can scrape the live service.
//!             {"trace": true} → drain the `util::trace` rings as
//!             Chrome trace_event JSON (one line; Perfetto-loadable).
//!
//! Tracing (`util::trace`, ROADMAP.md "Tracing"): each request line
//! can become a span tree `serve.accept → parse → route → queue →
//! linger → featurize → score → reply`, tagged with shard and batch
//! ids. A request is traced when the client supplied a `"trace_id"`
//! (16 hex digits — explicit propagation bypasses sampling) or when
//! the `COGNATE_TRACE_SAMPLE` sampler hits; the id is echoed in the
//! reply either way. Jobs carry their `TraceCtx` across the router
//! into whichever shard dequeues them; the shard backfills the queue /
//! linger / featurize intervals via `trace::record` since it only
//! learns their boundaries after the fact.
//!
//! Telemetry (canonical names in ROADMAP.md "Telemetry"): every job
//! dequeued by ANY shard bumps `serve.jobs_total` and observes
//! `serve.queue_wait_us` exactly once, so `queue_wait_us.count ==
//! jobs_total` whenever the service is quiescent — the invariant is
//! global across shards. Per-shard instanced metrics
//! (`serve.shard_jobs_total.<i>`, `serve.shard_linger_us.<i>`) are
//! registered through `registry()` directly, never the macros (a
//! macro call site caches one name forever). Error replies of any kind
//! bump `serve.errors_total` exactly once — every error reply is built
//! by [`error_reply`], the single site that touches the counter.
//!
//! This file is a `cognate-lint` panic-free zone: no `unwrap`/`expect`/
//! `panic!`/slice indexing outside `#[cfg(test)]` — a malformed client
//! payload must become a JSON error reply, never a dead shard thread.

use crate::config::PlatformId;
use crate::dataset::MatrixRecord;
use crate::model::ModelDriver;
use crate::search::top_k;
use crate::sparse::features::density_map;
use crate::sparse::Csr;
use crate::train::{config_features, ConfigFeatures, ZEncoder};
use crate::util::json::Json;
use crate::util::trace::{self, TraceCtx, TraceSpan};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

pub struct Job {
    pub id: i64,
    pub k: usize,
    pub matrix: Csr,
    pub reply: mpsc::Sender<Json>,
    pub arrived: Instant,
    /// Trace context carried across the router (`NONE` = untraced; the
    /// shard's backfilled spans parent to `trace.span`, the request's
    /// `serve.accept` root).
    pub trace: TraceCtx,
    /// Arrival timestamp on the trace clock (`trace::now_us`), so the
    /// dequeuing shard can backfill the `serve.queue` interval. 0 when
    /// untraced.
    pub t0_us: u64,
}

/// Default (and adaptive-cap) linger window for batch coalescing.
pub const LINGER: Duration = Duration::from_millis(8);
/// Floor for the adaptive linger window: below this the coalescing win
/// is noise next to the syscall + wakeup cost of the wait itself.
pub const LINGER_MIN: Duration = Duration::from_micros(500);
/// Bounded per-shard queue depth (backpressure point for the router).
pub const DEFAULT_QUEUE_CAP: usize = 256;
/// Idle shards poll the shutdown flag at this interval.
const SHARD_POLL: Duration = Duration::from_millis(50);

/// How a shard sizes its batch-coalescing window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LingerPolicy {
    /// Constant window (the seed behaviour at `Fixed(LINGER)`).
    Fixed(Duration),
    /// Histogram-guided controller bounded to `[min, max]`.
    Adaptive { min: Duration, max: Duration },
}

impl LingerPolicy {
    /// Adaptive window in `[LINGER_MIN, max]` (min is clipped to the
    /// cap so degenerate caps still give a valid range).
    pub fn adaptive_to(max: Duration) -> LingerPolicy {
        LingerPolicy::Adaptive { min: LINGER_MIN.min(max), max }
    }
}

impl Default for LingerPolicy {
    fn default() -> Self {
        LingerPolicy::adaptive_to(LINGER)
    }
}

/// Service shape: shard count, linger policy, job budget, queue bound.
#[derive(Clone, Copy, Debug)]
pub struct ServeOpts {
    pub shards: usize,
    pub linger: LingerPolicy,
    /// Serve until this many *jobs* have been answered (`None` =
    /// forever). The budget is global across shards.
    pub max_jobs: Option<usize>,
    pub queue_cap: usize,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            shards: 1,
            linger: LingerPolicy::default(),
            max_jobs: None,
            queue_cap: DEFAULT_QUEUE_CAP,
        }
    }
}

impl ServeOpts {
    /// The common test shape: defaults plus a job budget.
    pub fn with_max_jobs(max_jobs: Option<usize>) -> ServeOpts {
        ServeOpts { max_jobs, ..ServeOpts::default() }
    }
}

/// Per-shard linger controller. The decision inputs are the shard's own
/// batch outcomes — the same signals the `serve.batch_size` /
/// `serve.queue_wait_us` histograms record:
/// * batch filled before the deadline → the window only adds latency →
///   shrink by 1/4;
/// * batch ≤ 1/4 full while the first job had already waited at least a
///   full window before we dequeued it (backlog) → the shard is the
///   bottleneck and wider coalescing amortises the PJRT call → double;
/// * batch ≤ 1/4 full with no backlog → traffic is light → shrink so
///   lone jobs aren't held hostage.
///
/// `backlog_wait` must be the first job's arrival→dequeue time measured
/// BEFORE lingering: `serve.queue_wait_us` itself includes the linger
/// window, so using it would make every lone job look like load.
pub struct AdaptiveLinger {
    policy: LingerPolicy,
    cur: Duration,
}

impl AdaptiveLinger {
    pub fn new(policy: LingerPolicy) -> AdaptiveLinger {
        let cur = match policy {
            LingerPolicy::Fixed(d) => d,
            LingerPolicy::Adaptive { min, .. } => min,
        };
        AdaptiveLinger { policy, cur }
    }

    /// Current coalescing window.
    pub fn window(&self) -> Duration {
        self.cur
    }

    /// Feed one batch outcome into the controller.
    pub fn on_batch(
        &mut self,
        batch_len: usize,
        feat_b: usize,
        filled_early: bool,
        backlog_wait: Duration,
    ) {
        let LingerPolicy::Adaptive { min, max } = self.policy else {
            return;
        };
        if filled_early && batch_len >= feat_b {
            self.cur = (self.cur * 3 / 4).clamp(min, max);
        } else if batch_len * 4 <= feat_b {
            if backlog_wait >= self.cur {
                self.cur = (self.cur * 2).clamp(min, max);
            } else {
                self.cur = (self.cur * 3 / 4).clamp(min, max);
            }
        }
    }
}

/// What a shard needs from its model replica. `ModelDriver` is the
/// production impl (`DriverServeModel`); benches substitute a synthetic
/// backend so batching policy can be measured without PJRT artifacts.
pub trait ServeModel: Send {
    /// Featurizer batch width — the coalescing target.
    fn feat_b(&self) -> usize;
    /// Embed a batch of density maps (one backend call per batch).
    fn featurize(&mut self, dmaps: &[&[f32]]) -> Result<Vec<Vec<f32>>>;
    /// Score every config of one matrix given its embedding.
    fn score(&mut self, embed: &[f32], cols: usize) -> Result<Vec<f64>>;
}

/// Upper bound on memoized per-`cols` config featurizations per shard.
/// SPADE's mapped vectors depend on the matrix column count, so an
/// adversarial client could otherwise grow the cache without bound.
const FEATS_CACHE_CAP: usize = 64;

/// Production `ServeModel`: a `ModelDriver` replica plus the serve-time
/// caches — the shared z encoding and per-`cols` config features
/// (previously rebuilt per job in the scoring loop).
pub struct DriverServeModel {
    driver: ModelDriver,
    platform: PlatformId,
    z_all: Arc<Vec<f32>>,
    feats_by_cols: HashMap<usize, ConfigFeatures>,
}

impl DriverServeModel {
    pub fn new(driver: ModelDriver, platform: PlatformId, z_all: Arc<Vec<f32>>) -> Self {
        DriverServeModel { driver, platform, z_all, feats_by_cols: HashMap::new() }
    }
}

impl ServeModel for DriverServeModel {
    fn feat_b(&self) -> usize {
        self.driver.feat_b()
    }

    fn featurize(&mut self, dmaps: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        self.driver.featurize(dmaps)
    }

    fn score(&mut self, embed: &[f32], cols: usize) -> Result<Vec<f64>> {
        if self.feats_by_cols.len() >= FEATS_CACHE_CAP && !self.feats_by_cols.contains_key(&cols)
        {
            self.feats_by_cols.clear();
        }
        let platform = self.platform;
        let feats =
            self.feats_by_cols.entry(cols).or_insert_with(|| config_features(platform, cols));
        let (cfg, _) = feats.cfg_for_variant(&self.driver.variant);
        self.driver.score_configs(embed, cfg, &self.z_all)
    }
}

/// Run the service until the job budget is spent (`opts.max_jobs`,
/// `None` = forever). All shards and the accept loop key off the same
/// global job count: the shard that exhausts the budget raises the
/// shutdown flag and wakes the acceptor, so a single connection sending
/// many requests consumes the budget exactly like many connections
/// sending one each. A batch in flight is always completed, so slightly
/// more than `max_jobs` jobs may be answered when the last batches
/// coalesced past the budget.
///
/// Returns the bound address via the callback before serving.
pub fn serve(
    driver: ModelDriver,
    zenc: ZEncoder,
    platform: PlatformId,
    addr: &str,
    opts: ServeOpts,
    on_ready: impl FnOnce(std::net::SocketAddr) + Send + 'static,
) -> Result<()> {
    let rt = driver.runtime().clone();
    let (het_dim, latent_dim) = (rt.dim("HET_DIM"), rt.dim("LATENT_DIM"));
    // het → z is matrix-independent: encode once, share across shards.
    let feats0 = config_features(platform, 4096);
    let z_all = Arc::new(zenc.encode(&feats0.het, het_dim, latent_dim).context("z encoding")?);
    let models: Vec<Box<dyn ServeModel>> = driver
        .replicate(opts.shards.max(1))
        .into_iter()
        .map(|d| Box::new(DriverServeModel::new(d, platform, z_all.clone())) as Box<dyn ServeModel>)
        .collect();
    serve_models(models, addr, opts, on_ready)
}

/// Backend-generic service entry: one shard per model. `serve` wraps
/// driver replicas; `bench_serve` feeds synthetic models through here.
pub fn serve_models(
    models: Vec<Box<dyn ServeModel>>,
    addr: &str,
    opts: ServeOpts,
    on_ready: impl FnOnce(std::net::SocketAddr) + Send + 'static,
) -> Result<()> {
    anyhow::ensure!(!models.is_empty(), "serve_models needs at least one shard");
    let listener = TcpListener::bind(addr).context("bind")?;
    let local = listener.local_addr()?;
    let done = Arc::new(AtomicBool::new(false));
    let served = Arc::new(AtomicUsize::new(0));

    let mut shard_threads = Vec::new();
    let mut shards = Vec::new();
    for (idx, model) in models.into_iter().enumerate() {
        let (tx, rx) = mpsc::sync_channel::<Job>(opts.queue_cap.max(1));
        let depth = Arc::new(AtomicUsize::new(0));
        let ctl = ShardCtl {
            idx,
            linger: AdaptiveLinger::new(opts.linger),
            depth: depth.clone(),
            done: done.clone(),
            served: served.clone(),
            max_jobs: opts.max_jobs,
            local,
        };
        // Named so logger/trace output identifies the shard.
        let t = std::thread::Builder::new()
            .name(format!("shard-{idx}"))
            .spawn(move || shard_loop(model, rx, ctl))
            .context("spawn shard thread")?;
        shard_threads.push(t);
        shards.push(ShardHandle { tx, depth });
    }
    let router = Arc::new(Router { shards, done: done.clone() });
    on_ready(local);

    // Acceptor: one handler thread per connection (connections are few;
    // the expensive resource — the model — is behind the queues anyway).
    for stream in listener.incoming() {
        if done.load(Ordering::Acquire) {
            break;
        }
        let Ok(stream) = stream else { continue };
        crate::counter!("serve.connections_total").inc();
        let router = router.clone();
        let _ = std::thread::Builder::new().name("conn".into()).spawn(move || {
            let _ = handle_conn(stream, &router);
        });
    }
    drop(router);
    for t in shard_threads {
        let _ = t.join();
    }
    Ok(())
}

struct ShardHandle {
    tx: mpsc::SyncSender<Job>,
    /// Queued + in-flight jobs: incremented by the router on enqueue,
    /// decremented by the shard after the reply is sent.
    depth: Arc<AtomicUsize>,
}

/// Least-loaded job router shared by every connection handler.
pub struct Router {
    shards: Vec<ShardHandle>,
    done: Arc<AtomicBool>,
}

impl Router {
    /// Enqueue on the shallowest shard queue; on `Err` the service is
    /// shutting down and the job was not enqueued.
    fn route(&self, job: Job) -> std::result::Result<(), Box<Job>> {
        if self.done.load(Ordering::Acquire) {
            return Err(Box::new(job));
        }
        let mut order: Vec<&ShardHandle> = self.shards.iter().collect();
        order.sort_by_key(|s| s.depth.load(Ordering::Relaxed));
        let Some(&least) = order.first() else {
            return Err(Box::new(job));
        };
        crate::histogram!("serve.router_depth")
            .observe(least.depth.load(Ordering::Relaxed) as u64);
        let mut job = job;
        for s in &order {
            s.depth.fetch_add(1, Ordering::Relaxed);
            match s.tx.try_send(job) {
                Ok(()) => return Ok(()),
                Err(mpsc::TrySendError::Full(j)) => {
                    s.depth.fetch_sub(1, Ordering::Relaxed);
                    crate::counter!("serve.router_overflow_total").inc();
                    job = j;
                }
                Err(mpsc::TrySendError::Disconnected(j)) => {
                    s.depth.fetch_sub(1, Ordering::Relaxed);
                    job = j;
                }
            }
        }
        // Every bounded queue is full (or its shard is gone): apply
        // backpressure by blocking on the least-loaded shard instead of
        // shedding the job.
        least.depth.fetch_add(1, Ordering::Relaxed);
        match least.tx.send(job) {
            Ok(()) => Ok(()),
            Err(mpsc::SendError(j)) => {
                least.depth.fetch_sub(1, Ordering::Relaxed);
                Err(Box::new(j))
            }
        }
    }
}

struct ShardCtl {
    idx: usize,
    linger: AdaptiveLinger,
    depth: Arc<AtomicUsize>,
    done: Arc<AtomicBool>,
    /// Global served-jobs count — the shared `max_jobs` budget.
    served: Arc<AtomicUsize>,
    max_jobs: Option<usize>,
    local: std::net::SocketAddr,
}

fn shard_loop(mut model: Box<dyn ServeModel>, rx: mpsc::Receiver<Job>, mut ctl: ShardCtl) {
    let feat_b = model.feat_b().max(1);
    // Instanced per-shard metrics: registered via `registry()` directly
    // because the macros cache one name per call site (every shard
    // would otherwise alias the first shard's cell).
    let reg = crate::util::metrics::registry();
    let jobs_ctr = reg.counter(&format!("serve.shard_jobs_total.{}", ctl.idx));
    let linger_gauge = reg.gauge(&format!("serve.shard_linger_us.{}", ctl.idx));
    linger_gauge.set(ctl.linger.window().as_micros() as f64);
    // Per-shard batch ordinal, attached as the `batch` span arg so one
    // exported trace shows which jobs coalesced together.
    let mut batch_seq: u64 = 0;

    loop {
        if ctl.done.load(Ordering::Acquire) {
            break;
        }
        // Bounded wait so an idle shard notices another shard spending
        // the budget (the blocking `recv` of the seed would sleep
        // through shutdown).
        let first = match rx.recv_timeout(SHARD_POLL) {
            Ok(j) => j,
            Err(mpsc::RecvTimeoutError::Timeout) => continue,
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        };
        // Controller load signal: how long the head job sat queued
        // BEFORE lingering (queue_wait_us includes the linger window
        // and would make every lone job look like backlog).
        let backlog_wait = first.arrived.elapsed();
        // Dynamic batching: collect more jobs within the linger window,
        // up to the featurizer batch width. `pops` stamps (trace clock)
        // when each traced job left the channel, splitting its wait
        // into queue (channel) and linger (batch-coalescing) spans.
        let mut batch = Vec::with_capacity(feat_b);
        let mut pops = Vec::with_capacity(feat_b);
        pops.push(if first.trace.active() { trace::now_us() } else { 0 });
        batch.push(first);
        let deadline = Instant::now() + ctl.linger.window();
        while batch.len() < feat_b {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(job) => {
                    pops.push(if job.trace.active() { trace::now_us() } else { 0 });
                    batch.push(job);
                }
                Err(_) => break,
            }
        }
        let filled_early = batch.len() >= feat_b && Instant::now() < deadline;
        let n_batched = batch.len();
        let dequeued = Instant::now();
        batch_seq += 1;
        let (shard_arg, batch_arg) = (ctl.idx as i64, batch_seq as i64);
        // One traced job makes the whole batch's umbrella span worth
        // emitting (parented under that job's request tree).
        let batch_tctx = batch.iter().find(|j| j.trace.active()).map(|j| j.trace);
        let dequeued_us = if batch_tctx.is_some() { trace::now_us() } else { 0 };
        for (job, pop) in batch.iter().zip(pops.iter()) {
            if job.trace.active() {
                trace::record(
                    "serve.queue",
                    job.trace,
                    job.t0_us,
                    pop.saturating_sub(job.t0_us),
                    &[("shard", shard_arg)],
                );
                trace::record(
                    "serve.linger",
                    job.trace,
                    *pop,
                    dequeued_us.saturating_sub(*pop),
                    &[("shard", shard_arg), ("batch", batch_arg)],
                );
            }
        }
        crate::histogram!("serve.batch_size").observe(n_batched as u64);
        // One queue-wait observation and one jobs_total bump per job —
        // adjacent so the stats invariant has no wide race window.
        for job in &batch {
            crate::histogram!("serve.queue_wait_us")
                .observe_duration(dequeued.duration_since(job.arrived));
            crate::counter!("serve.jobs_total").inc();
        }
        jobs_ctr.add(n_batched as u64);

        let dmaps: Vec<Vec<f32>> = batch.iter().map(|j| density_map(&j.matrix)).collect();
        let dmap_refs: Vec<&[f32]> = dmaps.iter().map(|d| d.as_slice()).collect();
        let t_feat = Instant::now();
        let t_feat_us = if batch_tctx.is_some() { trace::now_us() } else { 0 };
        let featurized = model.featurize(&dmap_refs);
        let feat_elapsed = t_feat.elapsed();
        crate::histogram!("serve.featurize_us").observe_duration(feat_elapsed);
        if batch_tctx.is_some() {
            // One backend call serves the whole batch: every traced job
            // gets the shared featurize interval in its own tree.
            let feat_end_us = trace::now_us();
            for job in &batch {
                if job.trace.active() {
                    trace::record(
                        "serve.featurize",
                        job.trace,
                        t_feat_us,
                        feat_end_us.saturating_sub(t_feat_us),
                        &[("shard", shard_arg), ("batch", batch_arg)],
                    );
                }
            }
        }
        match featurized {
            Ok(embeds) => {
                // featurize_ms is shared across the batch (one call).
                let featurize_ms = feat_elapsed.as_secs_f64() * 1e3;
                for (job, embed) in batch.into_iter().zip(embeds) {
                    let queue_wait_ms =
                        dequeued.duration_since(job.arrived).as_secs_f64() * 1e3;
                    let t_score = Instant::now();
                    let score_span = TraceSpan::child("serve.score", job.trace)
                        .arg("shard", shard_arg)
                        .arg("batch", batch_arg);
                    let scored = model.score(&embed, job.matrix.cols);
                    drop(score_span);
                    let score_elapsed = t_score.elapsed();
                    crate::histogram!("serve.score_us").observe_duration(score_elapsed);
                    let resp = match scored {
                        Ok(scores) => {
                            let top = top_k(&scores, job.k);
                            let top_scores: Vec<f64> =
                                top.iter().filter_map(|&i| scores.get(i).copied()).collect();
                            Json::obj(vec![
                                ("id", Json::Num(job.id as f64)),
                                ("top", Json::arr_usize(&top)),
                                ("scores", Json::arr_f64(&top_scores)),
                                (
                                    "latency_ms",
                                    Json::Num(job.arrived.elapsed().as_secs_f64() * 1e3),
                                ),
                                ("batched_with", Json::Num(n_batched as f64)),
                                ("shard", Json::Num(ctl.idx as f64)),
                                (
                                    "stages",
                                    Json::obj(vec![
                                        ("queue_wait_ms", Json::Num(queue_wait_ms)),
                                        ("featurize_ms", Json::Num(featurize_ms)),
                                        (
                                            "score_ms",
                                            Json::Num(score_elapsed.as_secs_f64() * 1e3),
                                        ),
                                    ]),
                                ),
                            ])
                        }
                        Err(e) => error_reply(format!("score: {e}")),
                    };
                    let _ = job.reply.send(resp);
                    ctl.depth.fetch_sub(1, Ordering::Relaxed);
                }
            }
            Err(e) => {
                for job in &batch {
                    let _ = job.reply.send(error_reply(format!("featurize: {e}")));
                    ctl.depth.fetch_sub(1, Ordering::Relaxed);
                }
            }
        }
        if let Some(tctx) = batch_tctx {
            trace::record(
                "serve.batch",
                tctx,
                dequeued_us,
                trace::now_us().saturating_sub(dequeued_us),
                &[("shard", shard_arg), ("batch", batch_arg)],
            );
        }

        ctl.linger.on_batch(n_batched, feat_b, filled_early, backlog_wait);
        let window_us = ctl.linger.window().as_micros() as f64;
        linger_gauge.set(window_us);
        // Global view: last shard to finish a batch wins (documented).
        crate::gauge!("serve.linger_us").set(window_us);

        // Errored jobs still consume budget (parity with the seed).
        let total = ctl.served.fetch_add(n_batched, Ordering::Relaxed) + n_batched;
        if matches!(ctl.max_jobs, Some(mj) if total >= mj) {
            ctl.done.store(true, Ordering::Release);
            // Wake the acceptor so it observes the flag and exits.
            let _ = TcpStream::connect(ctl.local);
            break;
        }
    }
}

/// Build an error reply, bumping `serve.errors_total` — the only call
/// site that touches the counter, so "exactly once per error reply"
/// holds by construction. (The audit that motivated this: the
/// parse-error and oversized-dimension paths each had their own bump
/// next to their own `Json::obj`, which stayed correct only as long as
/// nobody added a reply without a bump or a bump without a reply.)
fn error_reply(msg: String) -> Json {
    crate::counter!("serve.errors_total").inc();
    Json::obj(vec![("error", Json::Str(msg))])
}

/// Client-supplied trace id: 16 hex digits (the format replies echo).
/// 0 (absent / unparseable) means "let the sampler decide".
fn parse_trace_id(req: &Json) -> u64 {
    req.get("trace_id")
        .and_then(|v| v.as_str())
        .and_then(|s| u64::from_str_radix(s.trim(), 16).ok())
        .unwrap_or(0)
}

/// Echo the request's trace id into a reply object (success or error)
/// so clients can join replies to exported spans. No-op untraced.
fn echo_trace_id(resp: &mut Json, ctx: TraceCtx) {
    if !ctx.active() {
        return;
    }
    if let Json::Obj(m) = resp {
        m.insert("trace_id".to_string(), Json::Str(format!("{:016x}", ctx.trace_id)));
    }
}

fn handle_conn(stream: TcpStream, router: &Router) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        // Stamped before parsing: the accept root span is backdated
        // here once we know whether this line is traced.
        let t_line = trace::now_us();
        let req = match Json::parse(&line) {
            Ok(r) => r,
            Err(e) => {
                let err = error_reply(format!("bad request: {e}"));
                writeln!(writer, "{}", err.to_string())?;
                continue;
            }
        };
        // Control request: live metrics snapshot, answered here so it
        // works even while the scoring queues are saturated (and after
        // the job budget is spent, as long as the acceptor is up).
        if req.get("stats").and_then(|v| v.as_bool()) == Some(true) {
            crate::counter!("serve.stats_requests_total").inc();
            writeln!(
                writer,
                "{}",
                crate::util::metrics::registry().snapshot().to_string()
            )?;
            continue;
        }
        // Control request: drain completed spans as Chrome trace JSON
        // (answered here for the same reasons as {"stats": true}).
        if req.get("trace").and_then(|v| v.as_bool()) == Some(true) {
            crate::counter!("serve.trace_requests_total").inc();
            writeln!(writer, "{}", trace::to_chrome(&trace::drain()).to_string())?;
            continue;
        }
        // A client-supplied trace id always traces (explicit
        // propagation bypasses sampling); otherwise flip the sampler's
        // coin. The root interval starts back at t_line so the parse
        // span nests inside it.
        let client_tid = parse_trace_id(&req);
        let trace_id = if client_tid != 0 {
            client_tid
        } else if trace::sample_hit() {
            trace::next_id()
        } else {
            0
        };
        let root = TraceSpan::root_at("serve.accept", trace_id, t_line);
        let rctx = root.ctx();
        match parse_request(&req) {
            Ok((id, k, matrix)) => {
                if rctx.active() {
                    trace::record(
                        "serve.parse",
                        rctx,
                        t_line,
                        trace::now_us().saturating_sub(t_line),
                        &[("id", id)],
                    );
                }
                let (rtx, rrx) = mpsc::channel();
                let t0_us = if rctx.active() { trace::now_us() } else { 0 };
                let job = Job {
                    id,
                    k,
                    matrix,
                    reply: rtx,
                    arrived: Instant::now(),
                    trace: rctx,
                    t0_us,
                };
                let route_span = TraceSpan::child("serve.route", rctx);
                let routed = router.route(job);
                drop(route_span);
                match routed {
                    Ok(()) => {
                        let mut resp = rrx
                            .recv()
                            .unwrap_or_else(|_| error_reply("batcher died".into()));
                        echo_trace_id(&mut resp, rctx);
                        let reply_span = TraceSpan::child("serve.reply", rctx);
                        writeln!(writer, "{}", resp.to_string())?;
                        drop(reply_span);
                    }
                    Err(_) => {
                        // Shards already shut down (job budget spent):
                        // still reply with well-formed JSON.
                        let mut err = error_reply("service shutting down".into());
                        echo_trace_id(&mut err, rctx);
                        writeln!(writer, "{}", err.to_string())?;
                    }
                }
            }
            Err(e) => {
                let mut err = error_reply(e.to_string());
                echo_trace_id(&mut err, rctx);
                writeln!(writer, "{}", err.to_string())?;
            }
        }
        // `root` drops here: the serve.accept event closes only after
        // the reply (or error) hit the socket.
    }
    crate::debug!("connection from {peer:?} closed");
    Ok(())
}

/// Upper bound on request matrix dimensions. `rows`/`cols` size the CSR
/// allocation before any nonzero is validated, so without a cap a
/// single `{"rows": 1e18}` line would abort the process on a failed
/// allocation — the one panic no error reply can catch.
const MAX_DIM: usize = 1 << 26;

/// Parse a scoring request. Never panics on malformed input — every
/// missing/ill-typed/oversized field becomes an `Err` that the handler
/// turns into an `{"error": ...}` reply (and `serve.errors_total`).
fn parse_request(req: &Json) -> Result<(i64, usize, Csr)> {
    let id = req.get("id").and_then(|v| v.as_i64()).unwrap_or(0);
    let k = req.get("k").and_then(|v| v.as_usize()).unwrap_or(5);
    let rows = req
        .get("rows")
        .and_then(|v| v.as_usize())
        .context("missing or invalid \"rows\"")?;
    let cols = req
        .get("cols")
        .and_then(|v| v.as_usize())
        .context("missing or invalid \"cols\"")?;
    anyhow::ensure!(
        rows <= MAX_DIM && cols <= MAX_DIM,
        "matrix too large: rows/cols are capped at {MAX_DIM}"
    );
    let coo_json = req
        .get("coo")
        .and_then(|v| v.as_arr())
        .context("missing or invalid \"coo\"")?;
    let mut coo = Vec::with_capacity(coo_json.len());
    for e in coo_json {
        let t = e.as_arr().context("coo entry")?;
        anyhow::ensure!(t.len() >= 2, "coo entry needs [r, c] or [r, c, v]");
        let r = t.first().and_then(|x| x.as_usize()).context("r")? as u32;
        let c = t.get(1).and_then(|x| x.as_usize()).context("c")? as u32;
        let v = t.get(2).and_then(|x| x.as_f64()).unwrap_or(1.0) as f32;
        anyhow::ensure!((r as usize) < rows && (c as usize) < cols, "coo out of bounds");
        coo.push((r, c, v));
    }
    Ok((id, k, Csr::from_coo(rows, cols, coo)))
}

/// Serialise a scoring request for `m` as one JSON line (no trailing
/// newline). Written straight into one pre-sized `String` — the seed
/// built a `Json::Arr` with three boxed nodes per nonzero, which
/// dominated client-side request cost for large matrices.
pub fn request_payload(id: i64, k: usize, m: &Csr) -> String {
    request_payload_traced(id, k, m, 0)
}

/// [`request_payload`] with a trace id (16 hex digits in the wire
/// format); 0 omits the field, leaving the server's sampler in charge.
pub fn request_payload_traced(id: i64, k: usize, m: &Csr, trace_id: u64) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(96 + 16 * m.nnz());
    let _ = write!(s, "{{\"id\":{id},\"k\":{k},");
    if trace_id != 0 {
        let _ = write!(s, "\"trace_id\":\"{trace_id:016x}\",");
    }
    let _ = write!(s, "\"rows\":{},\"cols\":{},\"coo\":[", m.rows, m.cols);
    let mut first = true;
    for r in 0..m.rows {
        for (&c, &v) in m.row_indices(r).iter().zip(m.row_values(r)) {
            if !first {
                s.push(',');
            }
            first = false;
            let _ = write!(s, "[{r},{c},{v}]");
        }
    }
    s.push_str("]}");
    s
}

/// Blocking client helper (used by tests and the quickstart example).
pub fn request(addr: std::net::SocketAddr, id: i64, k: usize, m: &Csr) -> Result<Json> {
    request_traced(addr, id, k, m, 0)
}

/// [`request`] carrying a client-chosen trace id (0 = untraced unless
/// the server's sampler hits). The reply echoes the id as `trace_id`.
pub fn request_traced(
    addr: std::net::SocketAddr,
    id: i64,
    k: usize,
    m: &Csr,
    trace_id: u64,
) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{}", request_payload_traced(id, k, m, trace_id))?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
}

/// Fetch the drained span rings of a running service as Chrome-trace
/// JSON via the `{"trace": true}` control request.
pub fn request_trace(addr: std::net::SocketAddr) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{}", Json::obj(vec![("trace", Json::Bool(true))]).to_string())?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Json::parse(&line).map_err(|e| anyhow::anyhow!("bad trace response: {e}"))
}

/// Fetch a live telemetry snapshot from a running service via the
/// `{"stats": true}` control request.
pub fn request_stats(addr: std::net::SocketAddr) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{}", Json::obj(vec![("stats", Json::Bool(true))]).to_string())?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Json::parse(&line).map_err(|e| anyhow::anyhow!("bad stats response: {e}"))
}

/// Turn a request matrix into the record shape used by offline eval —
/// handy for tests comparing online vs offline answers.
pub fn record_for(m: &Csr, costs: Vec<f64>, name: &str) -> MatrixRecord {
    MatrixRecord {
        name: name.to_string(),
        dmap: density_map(m),
        cols: m.cols,
        rows: m.rows,
        nnz: m.nnz(),
        costs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MS: Duration = Duration::from_millis(1);

    #[test]
    fn fixed_linger_never_moves() {
        let mut l = AdaptiveLinger::new(LingerPolicy::Fixed(8 * MS));
        assert_eq!(l.window(), 8 * MS);
        l.on_batch(16, 16, true, Duration::ZERO);
        l.on_batch(1, 16, false, 100 * MS);
        assert_eq!(l.window(), 8 * MS);
    }

    #[test]
    fn adaptive_starts_at_min_and_grows_under_load() {
        let mut l = AdaptiveLinger::new(LingerPolicy::adaptive_to(8 * MS));
        assert_eq!(l.window(), LINGER_MIN);
        // Near-empty batches with the head job already waiting a full
        // window → double toward the cap.
        for _ in 0..20 {
            l.on_batch(1, 16, false, 100 * MS);
        }
        assert_eq!(l.window(), 8 * MS, "growth must clamp at the cap");
    }

    #[test]
    fn adaptive_shrinks_when_batches_fill_early() {
        let mut l = AdaptiveLinger::new(LingerPolicy::Adaptive { min: LINGER_MIN, max: 8 * MS });
        for _ in 0..5 {
            l.on_batch(1, 16, false, 100 * MS); // grow to the cap first
        }
        let grown = l.window();
        l.on_batch(16, 16, true, 100 * MS);
        assert!(l.window() < grown, "full-early batch must shrink the window");
    }

    #[test]
    fn adaptive_shrinks_when_near_empty_and_idle() {
        let mut l = AdaptiveLinger::new(LingerPolicy::Adaptive { min: LINGER_MIN, max: 8 * MS });
        for _ in 0..5 {
            l.on_batch(1, 16, false, 100 * MS);
        }
        let grown = l.window();
        // Lone job that had NOT been waiting (arrived into an idle
        // shard): don't hold it hostage next time.
        l.on_batch(1, 16, false, Duration::ZERO);
        assert!(l.window() < grown);
        // And repeated idle traffic bottoms out at the floor.
        for _ in 0..40 {
            l.on_batch(1, 16, false, Duration::ZERO);
        }
        assert_eq!(l.window(), LINGER_MIN);
    }

    #[test]
    fn adaptive_mid_batches_hold_steady() {
        let mut l = AdaptiveLinger::new(LingerPolicy::Adaptive { min: LINGER_MIN, max: 8 * MS });
        for _ in 0..3 {
            l.on_batch(1, 16, false, 100 * MS);
        }
        let w = l.window();
        // Half-full batch that hit the deadline: neither rule fires.
        l.on_batch(8, 16, false, 100 * MS);
        assert_eq!(l.window(), w);
    }

    #[test]
    fn adaptive_to_clips_min_to_cap() {
        let p = LingerPolicy::adaptive_to(Duration::from_micros(100));
        let LingerPolicy::Adaptive { min, max } = p else { panic!("adaptive") };
        assert!(min <= max);
        assert_eq!(max, Duration::from_micros(100));
    }

    #[test]
    fn parse_request_rejects_oversized_and_ragged_input() {
        // Dimension cap: a huge `rows` must become an error reply, not
        // an allocation abort.
        let huge =
            Json::parse(r#"{"rows": 281474976710656, "cols": 4, "coo": []}"#).unwrap();
        assert!(parse_request(&huge).is_err());
        // Ragged / ill-typed coo entries error instead of panicking.
        let ragged = Json::parse(r#"{"rows": 2, "cols": 2, "coo": [[0]]}"#).unwrap();
        assert!(parse_request(&ragged).is_err());
        let bad = Json::parse(r#"{"rows": 2, "cols": 2, "coo": [["x", 1]]}"#).unwrap();
        assert!(parse_request(&bad).is_err());
        // At the cap itself, requests still parse.
        let ok = Json::parse(r#"{"rows": 4, "cols": 4, "coo": [[0, 1, 2.0]]}"#).unwrap();
        assert!(parse_request(&ok).is_ok());
    }

    #[test]
    fn traced_payload_carries_and_parses_trace_id() {
        let m = Csr::from_coo(2, 2, vec![(0, 1, 1.0)]);
        let payload = request_payload_traced(3, 2, &m, 0xABCD);
        let req = Json::parse(&payload).expect("traced payload is valid JSON");
        assert_eq!(parse_trace_id(&req), 0xABCD);
        let (id, k, _) = parse_request(&req).expect("traced payload still parses");
        assert_eq!((id, k), (3, 2));
        // Untraced payloads omit the field entirely.
        let plain = Json::parse(&request_payload(3, 2, &m)).unwrap();
        assert_eq!(parse_trace_id(&plain), 0);
        assert!(plain.get("trace_id").is_none());
    }

    #[test]
    fn echo_trace_id_tags_replies_only_when_traced() {
        let mut r = Json::obj(vec![("id", Json::Num(1.0))]);
        echo_trace_id(&mut r, TraceCtx::NONE);
        assert!(r.get("trace_id").is_none());
        echo_trace_id(&mut r, TraceCtx { trace_id: 0xF00D, span: 1 });
        assert_eq!(
            r.get("trace_id").and_then(|v| v.as_str()),
            Some("000000000000f00d")
        );
    }

    #[test]
    fn request_payload_round_trips_through_parse_request() {
        let m = Csr::from_coo(
            3,
            4,
            vec![(0, 1, 2.0), (0, 3, 0.5), (1, 0, 1.0), (2, 2, 4.25)],
        );
        let payload = request_payload(7, 3, &m);
        let req = Json::parse(&payload).expect("payload must be valid JSON");
        let (id, k, parsed) = parse_request(&req).expect("payload must parse as a request");
        assert_eq!(id, 7);
        assert_eq!(k, 3);
        assert_eq!(parsed.rows, m.rows);
        assert_eq!(parsed.cols, m.cols);
        assert_eq!(parsed.nnz(), m.nnz());
        for r in 0..m.rows {
            assert_eq!(parsed.row_indices(r), m.row_indices(r));
            assert_eq!(parsed.row_values(r), m.row_values(r));
        }
    }
}
