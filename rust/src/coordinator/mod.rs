//! L3 coordinator: the end-to-end DSE pipeline.
//!
//! Owns the shared state every experiment needs — the matrix
//! collection, per-(platform, op) datasets (collected in parallel
//! through the simulators and cached on disk), the PJRT runtime, and
//! the scale knobs that shrink or grow experiments relative to the
//! paper's (4M CPU-hour) setup.

pub mod experiments;
pub mod serve;

use crate::config::PlatformId;
use crate::dataset::Dataset;
use crate::kernels::Op;
use crate::model::AeDriver;
use crate::platform::make_platform;
use crate::runtime::{artifacts_dir, Runtime};
use crate::sparse::{generate_collection, CollectionSpec, MatrixInfo};
use crate::train::{config_features, train_autoencoder, TrainOpts, ZEncoder};
use crate::util::pool::default_threads;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

/// Experiment scale. `Scale::small()` runs the full pipeline in minutes
/// on one machine; `--scale N` multiplies toward paper scale.
#[derive(Clone, Debug)]
pub struct Scale {
    pub per_cell: usize,
    pub max_dim: usize,
    /// Source (CPU) matrices for pre-training (paper: 100).
    pub pretrain_matrices: usize,
    /// Target matrices for few-shot fine-tuning (paper: 5).
    pub finetune_matrices: usize,
    /// Held-out matrices for evaluation (paper: 715).
    pub eval_matrices: usize,
    pub pretrain_opts: TrainOpts,
    pub finetune_opts: TrainOpts,
    pub ae_steps: usize,
    pub threads: usize,
    pub seed: u64,
}

impl Scale {
    pub fn small() -> Scale {
        Scale {
            per_cell: 3,
            max_dim: 2048,
            pretrain_matrices: 40,
            finetune_matrices: 5,
            eval_matrices: 20,
            pretrain_opts: TrainOpts {
                epochs: 8,
                batches_per_epoch: 28,
                val_matrices: 0,
                ..TrainOpts::default()
            },
            finetune_opts: TrainOpts {
                epochs: 5,
                batches_per_epoch: 14,
                val_matrices: 0,
                ..TrainOpts::default()
            },
            ae_steps: 300,
            threads: default_threads(),
            seed: 0xC0C0_A7E0,
        }
    }

    /// Smallest runnable scale: seconds per phase, for integration
    /// tests and CLI round-trip checks (`--scale micro`).
    pub fn micro() -> Scale {
        Scale {
            per_cell: 1,
            max_dim: 640,
            pretrain_matrices: 10,
            finetune_matrices: 3,
            eval_matrices: 8,
            pretrain_opts: TrainOpts {
                epochs: 3,
                batches_per_epoch: 10,
                val_matrices: 0,
                ..TrainOpts::default()
            },
            finetune_opts: TrainOpts {
                epochs: 2,
                batches_per_epoch: 6,
                val_matrices: 0,
                ..TrainOpts::default()
            },
            ae_steps: 60,
            threads: default_threads(),
            seed: 0xBEEF,
        }
    }

    /// Multiply the small scale toward the paper's setup.
    pub fn scaled(factor: usize) -> Scale {
        let mut s = Scale::small();
        if factor <= 1 {
            return s;
        }
        s.per_cell = (s.per_cell * factor).min(50); // 50×6×5 = 1500 matrices
        s.max_dim = (s.max_dim * factor.min(4)).min(16_384);
        s.pretrain_matrices = (s.pretrain_matrices * factor).min(1000);
        s.eval_matrices = (s.eval_matrices * factor).min(715);
        s.pretrain_opts.epochs = (s.pretrain_opts.epochs * factor).min(100);
        s.finetune_opts.epochs = (s.finetune_opts.epochs * factor).min(60);
        s.ae_steps = (s.ae_steps * factor).min(3000);
        s
    }
}

pub struct Pipeline {
    pub rt: Arc<Runtime>,
    pub scale: Scale,
    pub results_dir: PathBuf,
    collection: Option<Vec<MatrixInfo>>,
    datasets: HashMap<(PlatformId, Op), Arc<Dataset>>,
}

impl Pipeline {
    pub fn new(scale: Scale) -> Result<Pipeline> {
        let rt = Arc::new(Runtime::load(&artifacts_dir()).context("loading AOT artifacts")?);
        Ok(Pipeline {
            rt,
            scale,
            results_dir: PathBuf::from("results"),
            collection: None,
            datasets: HashMap::new(),
        })
    }

    /// The matrix collection (generated once, deterministic).
    pub fn collection(&mut self) -> &[MatrixInfo] {
        if self.collection.is_none() {
            let spec = CollectionSpec {
                seed: self.scale.seed,
                per_cell: self.scale.per_cell,
                max_dim: self.scale.max_dim,
            };
            crate::info!(
                "generating collection: {} matrices (max_dim={})",
                5 * 6 * spec.per_cell,
                spec.max_dim
            );
            self.collection = Some(generate_collection(&spec));
        }
        self.collection.as_ref().unwrap()
    }

    fn dataset_cache_path(&self, platform: PlatformId, op: Op) -> PathBuf {
        self.results_dir.join("cache").join(format!(
            "{}_{}_s{}_c{}_d{}.cds",
            platform.name(),
            op.name(),
            self.scale.seed,
            self.scale.per_cell,
            self.scale.max_dim
        ))
    }

    /// Dataset for (platform, op): disk cache → else collect in parallel.
    pub fn dataset(&mut self, platform: PlatformId, op: Op) -> Result<Arc<Dataset>> {
        if let Some(ds) = self.datasets.get(&(platform, op)) {
            return Ok(ds.clone());
        }
        let path = self.dataset_cache_path(platform, op);
        let ds = if path.exists() {
            crate::info!("loading cached dataset {path:?}");
            Dataset::load(&path)?
        } else {
            let threads = self.scale.threads;
            let sim = make_platform(platform);
            let coll: Vec<MatrixInfo> = self.collection().to_vec();
            crate::info!(
                "collecting {} × {} dataset over {} matrices ({threads} threads)",
                platform.name(),
                op.name(),
                coll.len()
            );
            let t0 = std::time::Instant::now();
            let ds = Dataset::collect(sim.as_ref(), op, &coll, threads);
            crate::info!("collected in {:.1}s", t0.elapsed().as_secs_f64());
            ds.save(&path)?;
            ds
        };
        let ds = Arc::new(ds);
        self.datasets.insert((platform, op), ds.clone());
        Ok(ds)
    }

    /// Deterministic matrix splits for a dataset: (pretrain/finetune pool,
    /// eval) — eval matrices never appear in any training set (§4.1).
    pub fn splits(&self, ds: &Dataset) -> (Vec<usize>, Vec<usize>) {
        let (train, eval) = ds.split(0.7, self.scale.seed ^ 0x517);
        let eval: Vec<usize> =
            eval.into_iter().take(self.scale.eval_matrices).collect();
        (train, eval)
    }

    /// Pre-training matrix subset (size-binned sampling like §4.1).
    pub fn pretrain_subset(&self, ds: &Dataset, pool: &[usize], n: usize) -> Vec<usize> {
        // Bin by rows, sample round-robin across bins for balance.
        let mut bins: Vec<Vec<usize>> = vec![Vec::new(); 5];
        for &i in pool {
            let r = ds.records[i].rows;
            let b = match r {
                0..=511 => 0,
                512..=1023 => 1,
                1024..=2047 => 2,
                2048..=4095 => 3,
                _ => 4,
            };
            bins[b].push(i);
        }
        let mut out = Vec::with_capacity(n);
        let mut cursor = vec![0usize; bins.len()];
        'outer: loop {
            let mut progressed = false;
            for (b, bin) in bins.iter().enumerate() {
                if cursor[b] < bin.len() {
                    out.push(bin[cursor[b]]);
                    cursor[b] += 1;
                    progressed = true;
                    if out.len() >= n {
                        break 'outer;
                    }
                }
            }
            if !progressed {
                break;
            }
        }
        out
    }

    /// Train options with per-epoch telemetry persistence wired to this
    /// pipeline's results dir (`metrics_epochs.jsonl`, appended as one
    /// snapshot line per epoch — the ROADMAP "persist training
    /// telemetry" surface).
    pub fn train_opts_with_telemetry(&self, base: &TrainOpts) -> TrainOpts {
        TrainOpts {
            metrics_jsonl: Some(self.results_dir.join("metrics_epochs.jsonl")),
            ..base.clone()
        }
    }

    /// Train the per-target autoencoder (§3.3) and wrap it as a ZEncoder.
    pub fn trained_ae(&mut self, platform: PlatformId, kind: &str, seed: i32) -> Result<ZEncoder> {
        let mut ae = AeDriver::init(self.rt.clone(), kind, seed)?;
        let het_dim = self.rt.dim("HET_DIM");
        let latent = self.rt.dim("LATENT_DIM");
        let batch = self.rt.dim("SCORE_B");
        let feats = config_features(platform, 4096);
        let losses = train_autoencoder(
            &mut ae,
            &feats.het,
            het_dim,
            latent,
            self.scale.ae_steps,
            batch,
            self.scale.seed ^ 0xAE,
        )?;
        crate::info!(
            "ae[{kind}/{}] trained: loss {:.4} → {:.4}",
            platform.name(),
            losses.first().copied().unwrap_or(f64::NAN as f32),
            losses.last().copied().unwrap_or(f64::NAN as f32)
        );
        Ok(ZEncoder::Ae(ae))
    }
}
