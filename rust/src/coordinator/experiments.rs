//! Experiment registry: one regenerator per paper table/figure
//! (DESIGN.md per-experiment index). Each prints an aligned table and
//! writes the same rows to `results/<id>.csv`.

use super::Pipeline;
use crate::baselines::{self, MethodCtx};
use crate::config::PlatformId;
use crate::dataset::Dataset;
use crate::kernels::Op;
use crate::model::pca::Pca;
use crate::model::ModelDriver;
use crate::search::{self, evaluate, oracle_summary, EvalSummary};
use crate::train::{config_features, train, ZEncoder};
use crate::util::stats;
use crate::util::table::Table;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::Arc;

pub const ALL_EXPERIMENTS: &[&str] = &[
    "table1", "fig2", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12",
    "table2", "fig13", "fig14", "fig15", "kernels",
];

/// Lazily-built shared state: datasets, AEs and pre-trained models are
/// reused across experiments in one `experiment all` run.
pub struct Workbench<'p> {
    pub pipe: &'p mut Pipeline,
    aes: HashMap<(PlatformId, &'static str), Arc<ZEncoder>>,
    pretrained: HashMap<(String, Op, usize), Arc<ModelDriver>>,
}

impl<'p> Workbench<'p> {
    pub fn new(pipe: &'p mut Pipeline) -> Self {
        Workbench { pipe, aes: HashMap::new(), pretrained: HashMap::new() }
    }

    fn ae(&mut self, platform: PlatformId, kind: &'static str) -> Result<Arc<ZEncoder>> {
        if let Some(z) = self.aes.get(&(platform, kind)) {
            return Ok(z.clone());
        }
        let z = Arc::new(self.pipe.trained_ae(platform, kind, platform.index() as i32 + 7)?);
        self.aes.insert((platform, kind), z.clone());
        Ok(z.clone())
    }

    /// Pre-train `variant` on CPU for `op` with `n_matrices` sources.
    fn pretrained(&mut self, variant: &str, op: Op, n_matrices: usize) -> Result<Arc<ModelDriver>> {
        let key = (variant.to_string(), op, n_matrices);
        if let Some(d) = self.pretrained.get(&key) {
            return Ok(d.clone());
        }
        let ds = self.pipe.dataset(PlatformId::Cpu, op)?;
        let (pool, _) = self.pipe.splits(&ds);
        let idx = self.pipe.pretrain_subset(&ds, &pool, n_matrices);
        let zenc = self.ae(PlatformId::Cpu, "ae")?;
        let mut driver = ModelDriver::init(self.pipe.rt.clone(), variant, 11)?;
        let opts = self.pipe.train_opts_with_telemetry(&self.pipe.scale.pretrain_opts);
        crate::info!("pretraining {variant} on cpu/{} with {} matrices", op.name(), idx.len());
        train(&mut driver, &zenc, &ds, &idx, &[], &opts)?;
        let d = Arc::new(driver);
        self.pretrained.insert(key, d.clone());
        Ok(d)
    }

    fn method_ctx<'a>(
        &self,
        source_ds: &'a Dataset,
        source_idx: &'a [usize],
        target_ds: &'a Dataset,
        finetune_idx: &'a [usize],
        eval_idx: &'a [usize],
        default_index: usize,
    ) -> MethodCtx<'a> {
        MethodCtx {
            rt: self.pipe.rt.clone(),
            source_ds,
            source_train_idx: source_idx,
            target_ds,
            finetune_idx,
            eval_idx,
            default_index,
            pretrain_opts: self.pipe.scale.pretrain_opts.clone(),
            finetune_opts: self.pipe.scale.finetune_opts.clone(),
            seed: 33,
        }
    }

    /// The standard (op, target) setup shared by most experiments.
    fn setup(&mut self, op: Op, target: PlatformId) -> Result<Setup> {
        let target_ds = self.pipe.dataset(target, op)?;
        let (pool, eval_idx) = self.pipe.splits(&target_ds);
        let finetune_idx: Vec<usize> =
            pool.iter().copied().take(self.pipe.scale.finetune_matrices).collect();
        let default_index = crate::config::default_config_index(target);
        Ok(Setup { target_ds, pool, eval_idx, finetune_idx, default_index })
    }
}

pub struct Setup {
    pub target_ds: Arc<Dataset>,
    pub pool: Vec<usize>,
    pub eval_idx: Vec<usize>,
    pub finetune_idx: Vec<usize>,
    pub default_index: usize,
}

pub fn run(pipe: &mut Pipeline, which: &str) -> Result<Vec<Table>> {
    let mut wb = Workbench::new(pipe);
    run_with(&mut wb, which)
}

/// Run one experiment against a SHARED workbench so pre-trained models,
/// AEs and datasets are reused across an `experiment all` sweep.
pub fn run_with(wb: &mut Workbench, which: &str) -> Result<Vec<Table>> {
    let tables = match which {
        "table1" => table1(),
        "fig2" => fig2_fig4(wb, &[Op::Spmm], &[PlatformId::Spade], "fig2")?,
        "fig4" => fig2_fig4(
            wb,
            &[Op::Spmm, Op::Sddmm],
            &[PlatformId::Spade, PlatformId::Gpu],
            "fig4",
        )?,
        "fig5" => per_matrix(wb, Op::Spmm, 1, "fig5")?,
        "fig6" => fig6(wb)?,
        "fig7" => variant_ablation(wb, &["cognate", "noife", "nofm", "nole"], "fig7")?,
        "fig8" => variant_ablation(wb, &["cognate", "tf", "gru"], "fig8")?,
        "fig9" => fig9(wb)?,
        "fig10" => fig10(wb)?,
        "fig11" => fig11(wb)?,
        "fig12" => fig12(wb)?,
        "table2" => table2(wb)?,
        "fig13" => per_matrix(wb, Op::Spmm, 5, "fig13")?,
        "fig14" => per_matrix(wb, Op::Sddmm, 1, "fig14")?,
        "fig15" => per_matrix(wb, Op::Sddmm, 5, "fig15")?,
        "kernels" => kernels_diag(wb)?,
        other => bail!("unknown experiment {other:?} (try: {})", ALL_EXPERIMENTS.join(", ")),
    };
    let dir = wb.pipe.results_dir.clone();
    for t in &tables {
        println!("{}", t.render());
        let name = t
            .title
            .split_whitespace()
            .next()
            .unwrap_or("out")
            .trim_end_matches(':')
            .to_lowercase();
        t.save_csv(&dir, &name)?;
    }
    Ok(tables)
}

/// Table 1 — config-parameter availability matrix (documentation check:
/// regenerated from the actual config spaces).
fn table1() -> Vec<Table> {
    let mut t = Table::new(
        "table1: program configuration parameters across platforms",
        &["param", "cpu", "gpu", "spade", "type"],
    );
    let rows = [
        ("loop strip-mining", "y", "y", "", "numerical"),
        ("loop reordering", "y", "y", "", "categorical"),
        ("format reordering", "y", "", "", "categorical"),
        ("loop binding", "", "y", "", "categorical"),
        ("loop unrolling", "", "y", "", "categorical"),
        ("tiling", "", "", "y", "numerical"),
        ("barrier", "", "", "y", "binary"),
        ("cache bypassing", "", "", "y", "binary"),
        ("matrix reordering", "", "", "y", "binary"),
    ];
    for (p, c, g, s, ty) in rows {
        t.row(vec![p.into(), c.into(), g.into(), s.into(), ty.into()]);
    }
    vec![t]
}

/// Figures 2 & 4 — headline method comparison.
fn fig2_fig4(
    wb: &mut Workbench,
    ops: &[Op],
    targets: &[PlatformId],
    id: &str,
) -> Result<Vec<Table>> {
    let mut t = Table::new(
        &format!("{id}: geomean speedups vs baseline (higher is better)"),
        &["op", "target", "method", "geomean", "max", "ape%", "frac_of_optimal"],
    );
    for &op in ops {
        let source_ds = wb.pipe.dataset(PlatformId::Cpu, op)?;
        let (source_pool, _) = wb.pipe.splits(&source_ds);
        let n_pre = wb.pipe.scale.pretrain_matrices;
        for &target in targets {
            let setup = wb.setup(op, target)?;
            let zenc_t = wb.ae(target, "ae")?;
            let ctx = wb.method_ctx(
                &source_ds,
                &source_pool,
                &setup.target_ds,
                &setup.finetune_idx,
                &setup.eval_idx,
                setup.default_index,
            );
            let oracle = oracle_summary(&setup.target_ds, &setup.eval_idx, setup.default_index);
            let mut push = |method: &str, s: &EvalSummary| {
                t.row(vec![
                    op.name().into(),
                    target.name().into(),
                    method.into(),
                    Table::f(s.geomean_speedup),
                    Table::f(s.max_speedup),
                    Table::f(s.ape),
                    Table::f(s.geomean_speedup / oracle.geomean_speedup),
                ]);
            };
            // COGNATE pre-trained once, reused for zero-shot + top-1/5.
            let pre = wb.pretrained("cognate", op, n_pre)?;
            let zs = baselines::zero_shot(&ctx, &pre, &zenc_t, 1)?;
            push("zero-shot", &zs);
            let nt = baselines::no_transfer(&ctx, "cognate", &zenc_t, 1)?;
            push("no-transfer", &nt);
            for variant in ["waco_fa", "waco_fm"] {
                let prew = wb.pretrained(variant, op, n_pre)?;
                let ctx2 = wb.method_ctx(
                    &source_ds,
                    &source_pool,
                    &setup.target_ds,
                    &setup.finetune_idx,
                    &setup.eval_idx,
                    setup.default_index,
                );
                let s = baselines::finetune_and_eval(&ctx2, &prew, &ZEncoder::Zero, 1)?;
                push(variant, &s);
            }
            let mut tuned = pre.fork_for_finetune();
            train(
                &mut tuned,
                &zenc_t,
                &setup.target_ds,
                &setup.finetune_idx,
                &[],
                &wb.pipe.scale.finetune_opts.clone(),
            )?;
            let top1 =
                evaluate(&tuned, &zenc_t, &setup.target_ds, &setup.eval_idx, setup.default_index, 1)?;
            push("cognate-top1", &top1);
            let top5 =
                evaluate(&tuned, &zenc_t, &setup.target_ds, &setup.eval_idx, setup.default_index, 5)?;
            push("cognate-top5", &top5);
            push("oracle", &oracle);
        }
    }
    Ok(vec![t])
}

/// Figures 5 / 13 / 14 / 15 — per-matrix speedups of the tuned model.
fn per_matrix(wb: &mut Workbench, op: Op, k: usize, id: &str) -> Result<Vec<Table>> {
    let target = PlatformId::Spade;
    let setup = wb.setup(op, target)?;
    let zenc = wb.ae(target, "ae")?;
    let pre = wb.pretrained("cognate", op, wb.pipe.scale.pretrain_matrices)?;
    let mut tuned = pre.fork_for_finetune();
    train(
        &mut tuned,
        &zenc,
        &setup.target_ds,
        &setup.finetune_idx,
        &[],
        &wb.pipe.scale.finetune_opts.clone(),
    )?;
    let s = evaluate(&tuned, &zenc, &setup.target_ds, &setup.eval_idx, setup.default_index, k)?;
    let mut t = Table::new(
        &format!("{id}: per-matrix speedups, cognate top-{k}, {} on spade", op.name()),
        &["matrix", "speedup", "optimal"],
    );
    let mut rows = s.per_matrix.clone();
    rows.sort_by(|a, b| b.speedup.partial_cmp(&a.speedup).unwrap());
    for e in rows {
        t.row(vec![e.name, Table::f(e.speedup), Table::f(e.optimal_speedup)]);
    }
    Ok(vec![t])
}

/// Figure 6 — PRL / OPA / K-τ training curves (pre-training on CPU).
fn fig6(wb: &mut Workbench) -> Result<Vec<Table>> {
    let op = Op::Spmm;
    let ds = wb.pipe.dataset(PlatformId::Cpu, op)?;
    let (pool, eval) = wb.pipe.splits(&ds);
    let idx = wb.pipe.pretrain_subset(&ds, &pool, wb.pipe.scale.pretrain_matrices);
    let zenc = wb.ae(PlatformId::Cpu, "ae")?;
    let mut driver = ModelDriver::init(wb.pipe.rt.clone(), "cognate", 5)?;
    let mut opts = wb.pipe.scale.pretrain_opts.clone();
    opts.val_matrices = 6.min(eval.len());
    opts.val_configs = 32;
    let logs = train(&mut driver, &zenc, &ds, &idx, &eval, &opts)?;
    let mut t = Table::new(
        "fig6: training loss and ranking accuracy per epoch",
        &["epoch", "train_prl", "val_prl", "opa", "ktau"],
    );
    for l in logs {
        t.row(vec![
            l.epoch.to_string(),
            Table::f(l.train_loss),
            Table::f(l.val_prl),
            Table::f(l.val_opa),
            Table::f(l.val_ktau),
        ]);
    }
    Ok(vec![t])
}

/// Figures 7 & 8 — model-component / predictor ablations.
fn variant_ablation(wb: &mut Workbench, variants: &[&str], id: &str) -> Result<Vec<Table>> {
    let (op, target) = (Op::Spmm, PlatformId::Spade);
    let setup = wb.setup(op, target)?;
    let zenc = wb.ae(target, "ae")?;
    let mut t = Table::new(
        &format!("{id}: ablation (spmm on spade, top-1)"),
        &["variant", "geomean", "ape%"],
    );
    for &variant in variants {
        let pre = wb.pretrained(variant, op, wb.pipe.scale.pretrain_matrices)?;
        let z: &ZEncoder = if variant == "nole" { &ZEncoder::Zero } else { &zenc };
        let mut tuned = pre.fork_for_finetune();
        train(
            &mut tuned,
            z,
            &setup.target_ds,
            &setup.finetune_idx,
            &[],
            &wb.pipe.scale.finetune_opts.clone(),
        )?;
        let s = evaluate(&tuned, z, &setup.target_ds, &setup.eval_idx, setup.default_index, 1)?;
        t.row(vec![variant.into(), Table::f(s.geomean_speedup), Table::f(s.ape)]);
    }
    Ok(vec![t])
}

/// Figure 9 — heterogeneous-component encodings: FA / PCA / AE / VAE.
fn fig9(wb: &mut Workbench) -> Result<Vec<Table>> {
    let (op, target) = (Op::Spmm, PlatformId::Spade);
    let setup = wb.setup(op, target)?;
    let pre = wb.pretrained("cognate", op, wb.pipe.scale.pretrain_matrices)?;
    let feats = config_features(target, 4096);
    let het_dim = wb.pipe.rt.dim("HET_DIM");
    let mut t = Table::new(
        "fig9: latent encodings of hardware-specific knobs (spmm/spade, top-1)",
        &["encoder", "geomean", "ape%"],
    );
    let encoders: Vec<(&str, Arc<ZEncoder>)> = vec![
        ("feature-augment", Arc::new(ZEncoder::RawHet)),
        ("pca", Arc::new(ZEncoder::Pca(Pca::fit(&feats.het, het_dim, 8)))),
        ("autoencoder", wb.ae(target, "ae")?),
        ("vae", wb.ae(target, "vae")?),
    ];
    for (name, z) in encoders {
        let mut tuned = pre.fork_for_finetune();
        train(
            &mut tuned,
            &z,
            &setup.target_ds,
            &setup.finetune_idx,
            &[],
            &wb.pipe.scale.finetune_opts.clone(),
        )?;
        let s = evaluate(&tuned, &z, &setup.target_ds, &setup.eval_idx, setup.default_index, 1)?;
        t.row(vec![name.into(), Table::f(s.geomean_speedup), Table::f(s.ape)]);
    }
    Ok(vec![t])
}

/// Figure 10 — data overhead without transfer learning (NT d sweep).
fn fig10(wb: &mut Workbench) -> Result<Vec<Table>> {
    let (op, target) = (Op::Spmm, PlatformId::Spade);
    let setup = wb.setup(op, target)?;
    let zenc = wb.ae(target, "ae")?;
    let mut t = Table::new(
        "fig10: no-transfer target-data sweep vs cognate TL-5",
        &["method", "target_matrices", "geomean", "ape%"],
    );
    let max_d = setup.pool.len();
    for d in [2usize, 5, 10, 20, 40] {
        if d > max_d {
            break;
        }
        let idx: Vec<usize> = setup.pool.iter().copied().take(d).collect();
        let mut driver = ModelDriver::init(wb.pipe.rt.clone(), "cognate", 99 + d as i32)?;
        let mut opts = wb.pipe.scale.pretrain_opts.clone();
        opts.epochs = (opts.epochs * 2).max(8);
        train(&mut driver, &zenc, &setup.target_ds, &idx, &[], &opts)?;
        let s = evaluate(&driver, &zenc, &setup.target_ds, &setup.eval_idx, setup.default_index, 1)?;
        t.row(vec!["NT".into(), d.to_string(), Table::f(s.geomean_speedup), Table::f(s.ape)]);
    }
    // Reference: transfer-learned with 5 matrices.
    let pre = wb.pretrained("cognate", op, wb.pipe.scale.pretrain_matrices)?;
    let mut tuned = pre.fork_for_finetune();
    train(
        &mut tuned,
        &zenc,
        &setup.target_ds,
        &setup.finetune_idx,
        &[],
        &wb.pipe.scale.finetune_opts.clone(),
    )?;
    let s = evaluate(&tuned, &zenc, &setup.target_ds, &setup.eval_idx, setup.default_index, 1)?;
    t.row(vec![
        "TL (cognate)".into(),
        setup.finetune_idx.len().to_string(),
        Table::f(s.geomean_speedup),
        Table::f(s.ape),
    ]);
    Ok(vec![t])
}

/// Figure 11 — negative transfer: source-dataset-size sweep.
fn fig11(wb: &mut Workbench) -> Result<Vec<Table>> {
    let (op, target) = (Op::Spmm, PlatformId::Spade);
    let setup = wb.setup(op, target)?;
    let zenc = wb.ae(target, "ae")?;
    let mut t = Table::new(
        "fig11: impact of source-dataset size (finetune on 5 target matrices)",
        &["source_matrices", "geomean", "ape%"],
    );
    let source_ds = wb.pipe.dataset(PlatformId::Cpu, op)?;
    let (pool, _) = wb.pipe.splits(&source_ds);
    for n in [3usize, 10, 25, 60, 90] {
        if n > pool.len() {
            break;
        }
        let pre = wb.pretrained("cognate", op, n)?;
        let mut tuned = pre.fork_for_finetune();
        train(
            &mut tuned,
            &zenc,
            &setup.target_ds,
            &setup.finetune_idx,
            &[],
            &wb.pipe.scale.finetune_opts.clone(),
        )?;
        let s = evaluate(&tuned, &zenc, &setup.target_ds, &setup.eval_idx, setup.default_index, 1)?;
        t.row(vec![n.to_string(), Table::f(s.geomean_speedup), Table::f(s.ape)]);
    }
    Ok(vec![t])
}

/// Figure 12 — number of fine-tuning matrices.
fn fig12(wb: &mut Workbench) -> Result<Vec<Table>> {
    let (op, target) = (Op::Spmm, PlatformId::Spade);
    let setup = wb.setup(op, target)?;
    let zenc = wb.ae(target, "ae")?;
    let pre = wb.pretrained("cognate", op, wb.pipe.scale.pretrain_matrices)?;
    let mut t = Table::new(
        "fig12: fine-tuning sample-count sweep",
        &["finetune_matrices", "geomean", "ape%"],
    );
    for d in [1usize, 3, 5, 7, 10, 20] {
        if d > setup.pool.len() {
            break;
        }
        let idx: Vec<usize> = setup.pool.iter().copied().take(d).collect();
        let mut tuned = pre.fork_for_finetune();
        train(
            &mut tuned,
            &zenc,
            &setup.target_ds,
            &idx,
            &[],
            &wb.pipe.scale.finetune_opts.clone(),
        )?;
        let s = evaluate(&tuned, &zenc, &setup.target_ds, &setup.eval_idx, setup.default_index, 1)?;
        t.row(vec![d.to_string(), Table::f(s.geomean_speedup), Table::f(s.ape)]);
    }
    Ok(vec![t])
}

/// Table 2 — speedup / APE / DCE across data-budget categories.
fn table2(wb: &mut Workbench) -> Result<Vec<Table>> {
    let (op, target) = (Op::Spmm, PlatformId::Spade);
    let setup = wb.setup(op, target)?;
    let zenc = wb.ae(target, "ae")?;
    let cfgs_per = wb.pipe.scale.pretrain_opts.configs_per_matrix;
    let beta_cpu = 1.0;
    let beta_spade = 1000.0;
    let dce = |cpu_m: usize, spade_m: usize| {
        (beta_cpu * (cpu_m * cfgs_per) as f64 + beta_spade * (spade_m * cfgs_per) as f64) / 1e6
    };
    let mut t = Table::new(
        "table2: cost-model performance vs data budget (spmm on spade)",
        &["model", "cpu_samples", "spade_samples", "top1_speedup", "ape%", "dce/1e6"],
    );
    let n_pre = wb.pipe.scale.pretrain_matrices;

    // NT d — target-only training.
    for d in [2usize, 5, 15] {
        if d > setup.pool.len() {
            break;
        }
        let idx: Vec<usize> = setup.pool.iter().copied().take(d).collect();
        let mut driver = ModelDriver::init(wb.pipe.rt.clone(), "cognate", 200 + d as i32)?;
        let mut opts = wb.pipe.scale.pretrain_opts.clone();
        opts.epochs = (opts.epochs * 2).max(8);
        train(&mut driver, &zenc, &setup.target_ds, &idx, &[], &opts)?;
        let s = evaluate(&driver, &zenc, &setup.target_ds, &setup.eval_idx, setup.default_index, 1)?;
        t.row(vec![
            format!("NT {d}"),
            "0".into(),
            (d * cfgs_per).to_string(),
            Table::f(s.geomean_speedup),
            Table::f(s.ape),
            Table::f(dce(0, d)),
        ]);
    }
    // TL d — pre-trained then fine-tuned on d.
    for d in [2usize, 5, 15] {
        if d > setup.pool.len() {
            break;
        }
        let pre = wb.pretrained("cognate", op, n_pre)?;
        let idx: Vec<usize> = setup.pool.iter().copied().take(d).collect();
        let mut tuned = pre.fork_for_finetune();
        train(
            &mut tuned,
            &zenc,
            &setup.target_ds,
            &idx,
            &[],
            &wb.pipe.scale.finetune_opts.clone(),
        )?;
        let s = evaluate(&tuned, &zenc, &setup.target_ds, &setup.eval_idx, setup.default_index, 1)?;
        t.row(vec![
            format!("TL {d} (CPU {n_pre})"),
            (n_pre * cfgs_per).to_string(),
            (d * cfgs_per).to_string(),
            Table::f(s.geomean_speedup),
            Table::f(s.ape),
            Table::f(dce(n_pre, d)),
        ]);
    }
    // CPU d — source-size sweep, fine-tuned on 5.
    for n in [10usize, 25, 60] {
        let pre = wb.pretrained("cognate", op, n)?;
        let mut tuned = pre.fork_for_finetune();
        train(
            &mut tuned,
            &zenc,
            &setup.target_ds,
            &setup.finetune_idx,
            &[],
            &wb.pipe.scale.finetune_opts.clone(),
        )?;
        let s = evaluate(&tuned, &zenc, &setup.target_ds, &setup.eval_idx, setup.default_index, 1)?;
        t.row(vec![
            format!("CPU {n}"),
            (n * cfgs_per).to_string(),
            (setup.finetune_idx.len() * cfgs_per).to_string(),
            Table::f(s.geomean_speedup),
            Table::f(s.ape),
            Table::f(dce(n, setup.finetune_idx.len())),
        ]);
    }
    // Zero-shot.
    let pre = wb.pretrained("cognate", op, n_pre)?;
    let s = evaluate(&pre, &zenc, &setup.target_ds, &setup.eval_idx, setup.default_index, 1)?;
    t.row(vec![
        "Zero-Shot (CPU)".into(),
        (n_pre * cfgs_per).to_string(),
        "0".into(),
        Table::f(s.geomean_speedup),
        Table::f(s.ape),
        Table::f(dce(n_pre, 0)),
    ]);
    Ok(vec![t])
}

/// Cross-platform landscape-correlation diagnostic (not a paper figure,
/// but the premise of Fig 1's pipeline — reported alongside).
pub fn correlation_diagnostic(pipe: &mut Pipeline, op: Op) -> Result<Table> {
    let cpu = pipe.dataset(PlatformId::Cpu, op)?;
    let spade = pipe.dataset(PlatformId::Spade, op)?;
    let mut t = Table::new(
        "diag: cpu↔spade optimal-config agreement",
        &["matrix", "spearman_mapped_cost"],
    );
    for (rc, rs) in cpu.records.iter().zip(spade.records.iter()).take(12) {
        // Correlate per-matrix cost over mapped (I, J) buckets.
        let xs: Vec<f64> = rc.costs.iter().map(|c| c.ln()).collect();
        let ys: Vec<f64> = rs.costs.iter().map(|c| c.ln()).collect();
        let n = xs.len().min(ys.len());
        let rho = stats::spearman(&xs[..n], &ys[..n]);
        t.row(vec![rc.name.clone(), Table::f(rho)]);
    }
    Ok(t)
}

/// `kernels` — parallel sparse-kernel scaling diagnostic (not a paper
/// figure; excluded from `run_all`). Times the nnz-balanced
/// `spmm_parallel` / `sddmm_parallel` on the heaviest collection
/// matrices at 1 vs `scale.threads` threads. Dataset collection and the
/// simulators ride on the same thread pool and partitioning, so this
/// table is the quick health check that the hot path actually scales.
fn kernels_diag(wb: &mut Workbench) -> Result<Vec<Table>> {
    use crate::kernels::{sddmm_parallel, spmm_parallel, SddmmSchedule, SpmmSchedule, DENSE_DIM};
    use crate::util::bench::bench;
    use crate::util::rng::Rng;

    let threads = wb.pipe.scale.threads.max(1);
    let coll = wb.pipe.collection();
    let mut by_nnz: Vec<usize> = (0..coll.len()).collect();
    by_nnz.sort_by_key(|&i| std::cmp::Reverse(coll[i].matrix.nnz()));

    let n = DENSE_DIM;
    let mut t = Table::new(
        "kernels: parallel kernel scaling on heaviest collection matrices",
        &["op", "matrix", "nnz", "threads", "mean_ms", "speedup"],
    );
    let mut rng = Rng::new(0xBE5C);
    let thread_counts: Vec<usize> = if threads > 1 { vec![1, threads] } else { vec![1] };
    for &mi in by_nnz.iter().take(3) {
        let info = &coll[mi];
        let m = &info.matrix;
        let b: Vec<f32> = (0..m.cols * n).map(|_| rng.next_f32() - 0.5).collect();
        let bt: Vec<f32> = (0..m.rows * n).map(|_| rng.next_f32() - 0.5).collect();
        let c: Vec<f32> = (0..n * m.cols).map(|_| rng.next_f32() - 0.5).collect();
        let ss = SpmmSchedule { i_block: 64, k_block: 32, outer_k: false };
        let sd = SddmmSchedule { i_block: 64, k_block: 32, outer_k: false };
        let mut out = vec![0f32; m.rows * n];
        let mut vals = vec![0f32; m.nnz()];
        let mut base = [0f64; 2];
        for &th in &thread_counts {
            let rs = bench("spmm", 1, 8, 0.5, || spmm_parallel(m, &b, n, ss, th, &mut out));
            let rd = bench("sddmm", 1, 8, 0.5, || sddmm_parallel(m, &bt, &c, n, sd, th, &mut vals));
            if th == 1 {
                base = [rs.mean_s, rd.mean_s];
            }
            for (op, r, b0) in [("spmm", &rs, base[0]), ("sddmm", &rd, base[1])] {
                t.row(vec![
                    op.into(),
                    info.name.clone(),
                    m.nnz().to_string(),
                    th.to_string(),
                    Table::f(r.mean_s * 1e3),
                    Table::f(b0 / r.mean_s.max(1e-12)),
                ]);
            }
        }
    }
    Ok(vec![t])
}

/// Convenience: run every experiment with one shared workbench, most
/// informative first (so partial sweeps still yield the headline).
pub fn run_all(pipe: &mut Pipeline) -> Result<()> {
    let order = [
        "table1", "fig4", "fig6", "fig5", "fig7", "fig9", "fig12", "fig10", "fig11", "table2",
        "fig8", "fig2", "fig13", "fig14", "fig15",
    ];
    let mut wb = Workbench::new(pipe);
    for id in order {
        crate::info!("=== experiment {id} ===");
        run_with(&mut wb, id)?;
    }
    Ok(())
}

// Silence unused-import warning for search::top_k re-export pathway.
#[allow(unused_imports)]
use search::top_k as _top_k;
