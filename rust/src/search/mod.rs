//! Top-k configuration search + evaluation (§4.1 "Cost Model
//! Evaluation"): predict the cost of every config, take the k best,
//! execute those on the target (here: look up their simulator cost) and
//! keep the fastest. Speedups are measured against the platform's
//! default configuration; the exhaustive optimum comes free from the
//! dataset's full cost vectors.
//!
//! For spaces too large to score exhaustively, `anneal` runs simulated
//! annealing whose neighbourhood moves are O(1): a config index is its
//! mixed-radix encoding over the knob radices (`config::radices`), so a
//! single-knob mutation is one digit replacement — no space rebuild, no
//! linear rescan. `par_anneal` distributes the restart chains across
//! threads with deterministic per-chain seeds and merges best-of, making
//! results independent of thread count.

pub mod anneal;

pub use anneal::{anneal, par_anneal, AnnealOpts, AnnealResult, Scorer};

use crate::dataset::{Dataset, MatrixRecord};
use crate::model::ModelDriver;
use crate::train::{config_features, ZEncoder};
use crate::util::stats;
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct MatrixEval {
    pub name: String,
    /// cost(default) / cost(best of top-k).
    pub speedup: f64,
    /// cost(default) / cost(optimal).
    pub optimal_speedup: f64,
    /// Chosen config's cost (for APE).
    pub chosen_cost: f64,
    pub optimal_cost: f64,
    pub chosen_index: usize,
}

#[derive(Clone, Debug, Default)]
pub struct EvalSummary {
    pub geomean_speedup: f64,
    pub geomean_optimal: f64,
    pub max_speedup: f64,
    pub ape: f64,
    pub per_matrix: Vec<MatrixEval>,
}

/// Indices of the k highest scores (higher score = predicted faster).
pub fn top_k(scores: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    idx.truncate(k.max(1));
    idx
}

/// Evaluate a trained model on `eval_idx` matrices with top-k selection.
pub fn evaluate(
    driver: &ModelDriver,
    zenc: &ZEncoder,
    ds: &Dataset,
    eval_idx: &[usize],
    default_index: usize,
    k: usize,
) -> Result<EvalSummary> {
    let rt = driver.runtime().clone();
    let (het_dim, latent_dim) = (rt.dim("HET_DIM"), rt.dim("LATENT_DIM"));
    let feats0 = config_features(ds.platform, ds.records[0].cols);
    let z_all = zenc.encode(&feats0.het, het_dim, latent_dim)?;
    let cfg_dim = driver.cfg_dim;

    let mut per_matrix = Vec::with_capacity(eval_idx.len());
    for &mi in eval_idx {
        let rec = &ds.records[mi];
        let scores = score_all(driver, zenc, ds, rec, Some(&z_all))?;
        per_matrix.push(eval_one(rec, &scores, default_index, k));
        let _ = cfg_dim;
    }
    Ok(summarize(per_matrix))
}

/// Score every config of one matrix (featurize once, batched scoring).
pub fn score_all(
    driver: &ModelDriver,
    zenc: &ZEncoder,
    ds: &Dataset,
    rec: &MatrixRecord,
    z_cache: Option<&[f32]>,
) -> Result<Vec<f64>> {
    let rt = driver.runtime().clone();
    let (het_dim, latent_dim) = (rt.dim("HET_DIM"), rt.dim("LATENT_DIM"));
    let feats = config_features(ds.platform, rec.cols);
    let z_all = match z_cache {
        Some(z) => z.to_vec(),
        None => zenc.encode(&feats.het, het_dim, latent_dim)?,
    };
    let (cfg, _dim) = feats.cfg_for_variant(&driver.variant);
    let s = driver.featurize(&[&rec.dmap])?.remove(0);
    driver.score_configs(&s, cfg, &z_all)
}

/// Pick the best of the k top-scored configs and compute speedups.
pub fn eval_one(rec: &MatrixRecord, scores: &[f64], default_index: usize, k: usize) -> MatrixEval {
    assert_eq!(scores.len(), rec.costs.len());
    let picks = top_k(scores, k);
    let (chosen_index, chosen_cost) = picks
        .iter()
        .map(|&i| (i, rec.costs[i]))
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    let default_cost = rec.costs[default_index];
    let optimal_cost = rec.optimal_cost();
    MatrixEval {
        name: rec.name.clone(),
        speedup: default_cost / chosen_cost,
        optimal_speedup: default_cost / optimal_cost,
        chosen_cost,
        optimal_cost,
        chosen_index,
    }
}

pub fn summarize(per_matrix: Vec<MatrixEval>) -> EvalSummary {
    let speedups: Vec<f64> = per_matrix.iter().map(|e| e.speedup).collect();
    let optimal: Vec<f64> = per_matrix.iter().map(|e| e.optimal_speedup).collect();
    let chosen: Vec<f64> = per_matrix.iter().map(|e| e.chosen_cost).collect();
    let opt: Vec<f64> = per_matrix.iter().map(|e| e.optimal_cost).collect();
    EvalSummary {
        geomean_speedup: stats::geomean(&speedups),
        geomean_optimal: stats::geomean(&optimal),
        max_speedup: stats::max(&speedups),
        ape: stats::ape(&chosen, &opt),
        per_matrix,
    }
}

/// The oracle selection (exhaustive search over true costs) — an upper
/// bound any cost model is measured against.
pub fn oracle_summary(ds: &Dataset, eval_idx: &[usize], default_index: usize) -> EvalSummary {
    let per: Vec<MatrixEval> = eval_idx
        .iter()
        .map(|&mi| {
            let rec = &ds.records[mi];
            let best = rec.optimal_index();
            MatrixEval {
                name: rec.name.clone(),
                speedup: rec.costs[default_index] / rec.costs[best],
                optimal_speedup: rec.costs[default_index] / rec.costs[best],
                chosen_cost: rec.costs[best],
                optimal_cost: rec.costs[best],
                chosen_index: best,
            }
        })
        .collect();
    summarize(per)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_orders_by_score_desc() {
        let scores = [0.1, 5.0, -2.0, 3.0];
        assert_eq!(top_k(&scores, 2), vec![1, 3]);
        assert_eq!(top_k(&scores, 1), vec![1]);
        // k larger than n clamps.
        assert_eq!(top_k(&scores, 10).len(), 4);
    }

    #[test]
    fn eval_one_picks_best_of_topk() {
        let rec = MatrixRecord {
            name: "t".into(),
            dmap: vec![],
            cols: 8,
            rows: 8,
            nnz: 4,
            costs: vec![100.0, 40.0, 60.0, 10.0, 90.0],
        };
        // Scores rank configs [4, 2, 1, 0, 3]: top-2 = {4, 2} → best cost 60.
        let scores = [1.0, 2.0, 4.0, 0.0, 5.0];
        let e = eval_one(&rec, &scores, 0, 2);
        assert_eq!(e.chosen_index, 2);
        assert!((e.speedup - 100.0 / 60.0).abs() < 1e-12);
        assert!((e.optimal_speedup - 10.0).abs() < 1e-12);
        // Top-5 reaches the optimum.
        let e5 = eval_one(&rec, &scores, 0, 5);
        assert_eq!(e5.chosen_index, 3);
        assert_eq!(e5.speedup, 10.0);
    }
}
