//! Simulated-annealing configuration search.
//!
//! §2.3: "Finding the best configuration suggested by the cost model is
//! usually done using auxiliary intelligent search techniques such as
//! simulated annealing…". Our evaluation spaces (256–1,024 points) allow
//! exhaustive scoring, but the framework also ships the SA searcher so
//! unconstrained spaces (the paper's "computationally infeasible" full
//! SPADE space) can be explored with a bounded number of cost-model
//! queries. Neighbourhoods are single-knob mutations in the structured
//! config space, computed as O(1) mixed-radix digit replacements — no
//! config decode, no space scan (see `config::space`).
//!
//! `par_anneal` runs the restart chains of an annealing job on separate
//! threads via `util::pool` and merges the best result; chain seeds are
//! derived deterministically from `AnnealOpts::seed`, so results are
//! reproducible and independent of the thread count.

// Determinism guard (clippy layer of the cognate-lint `determinism`
// rule, backed by clippy.toml's disallowed lists): SA decisions come
// from the seeded `util::rng::Rng` only, never hash order or clocks.
#![warn(clippy::disallowed_methods, clippy::disallowed_types)]

use crate::config::{knob_stride, radices, space_len, PlatformId};
use crate::util::pool::par_map;
use crate::util::rng::Rng;

/// A scorer maps a config index to a predicted score (higher = faster).
pub trait Scorer {
    fn score(&mut self, idx: usize) -> f64;
}

impl<F: FnMut(usize) -> f64> Scorer for F {
    fn score(&mut self, idx: usize) -> f64 {
        self(idx)
    }
}

#[derive(Clone, Debug)]
pub struct AnnealOpts {
    pub steps: usize,
    pub t_start: f64,
    pub t_end: f64,
    pub seed: u64,
    /// Restarts from random points (best-of-all returned).
    pub restarts: usize,
}

impl Default for AnnealOpts {
    fn default() -> Self {
        Self { steps: 200, t_start: 1.0, t_end: 0.01, seed: 7, restarts: 2 }
    }
}

#[derive(Clone, Debug)]
pub struct AnnealResult {
    pub best_index: usize,
    pub best_score: f64,
    pub evaluations: usize,
    /// Best score after each step (for convergence plots).
    pub trajectory: Vec<f64>,
}

/// Single-knob neighbour in the enumerated space of `platform`.
///
/// Pure mixed-radix digit arithmetic on the index: pick a knob, replace
/// its digit with a *different* value of the same radix. O(#knobs) work,
/// independent of the space size — no decode, no rescan. The result is
/// always in-space and always differs from `idx` in exactly one knob.
pub fn neighbor(platform: PlatformId, idx: usize, rng: &mut Rng) -> usize {
    let radix = radices(platform);
    let dim = rng.next_usize(radix.len());
    let r = radix[dim];
    let place = knob_stride(platform, dim);
    let old = (idx / place) % r;
    // Draw from the r-1 values != old, then shift past `old`.
    let mut new = rng.next_usize(r - 1);
    if new >= old {
        new += 1;
    }
    idx - old * place + new * place
}

pub fn space_size(platform: PlatformId) -> usize {
    space_len(platform)
}

/// Maximise the scorer over the platform's config space.
pub fn anneal<S: Scorer>(platform: PlatformId, scorer: &mut S, opts: &AnnealOpts) -> AnnealResult {
    let n = space_size(platform);
    let mut rng = Rng::new(opts.seed);
    let mut best_index = 0usize;
    let mut best_score = f64::NEG_INFINITY;
    let mut evaluations = 0usize;
    let mut accepts = 0usize;
    let mut proposals = 0usize;
    let mut trajectory = Vec::with_capacity(opts.steps * opts.restarts.max(1));
    for restart in 0..opts.restarts.max(1) {
        let mut cur = rng.next_usize(n);
        let mut cur_score = scorer.score(cur);
        evaluations += 1;
        if cur_score > best_score {
            best_score = cur_score;
            best_index = cur;
        }
        for step in 0..opts.steps {
            let frac = step as f64 / opts.steps.max(1) as f64;
            let temp = opts.t_start * (opts.t_end / opts.t_start).powf(frac);
            let cand = neighbor(platform, cur, &mut rng.fork(restart as u64 * 1000 + step as u64));
            let cand_score = scorer.score(cand);
            evaluations += 1;
            let accept = cand_score >= cur_score
                || rng.next_f64() < ((cand_score - cur_score) / temp.max(1e-12)).exp();
            proposals += 1;
            if accept {
                accepts += 1;
                cur = cand;
                cur_score = cand_score;
            }
            if cur_score > best_score {
                best_score = cur_score;
                best_index = cur;
            }
            trajectory.push(best_score);
        }
    }
    crate::counter!("sa.evals_total").add(evaluations as u64);
    if proposals > 0 {
        crate::gauge!("sa.accept_rate").set(accepts as f64 / proposals as f64);
    }
    crate::gauge!("sa.best_score").set(best_score);
    AnnealResult { best_index, best_score, evaluations, trajectory }
}

/// Seed stride between parallel annealing chains (golden-ratio odd
/// constant, so chain seeds are well spread even for small base seeds).
pub const CHAIN_SEED_STRIDE: u64 = 0x9E3779B97F4A7C15;

/// Seed of chain `i` of a parallel anneal with base options `opts`.
pub fn chain_seed(base: u64, chain: u64) -> u64 {
    base.wrapping_add(chain.wrapping_mul(CHAIN_SEED_STRIDE))
}

/// Run `opts.restarts` independent annealing chains across `threads`
/// worker threads and merge the best result.
///
/// Unlike `anneal`, the scorer must be `Fn + Sync` (it is shared across
/// threads); each chain runs a full single-restart anneal with a seed
/// derived from `opts.seed` via `chain_seed`, so the merged result is
/// identical for every thread count. Ties between chains resolve to the
/// lowest chain id. The merged trajectory is the concatenation of the
/// per-chain trajectories (chain order) rewritten as a running maximum,
/// preserving the monotonicity invariant of `anneal`.
pub fn par_anneal<F>(
    platform: PlatformId,
    scorer: &F,
    opts: &AnnealOpts,
    threads: usize,
) -> AnnealResult
where
    F: Fn(usize) -> f64 + Sync,
{
    let chains: Vec<u64> = (0..opts.restarts.max(1) as u64).collect();
    let results = par_map(&chains, threads, |_, &chain| {
        let chain_opts = AnnealOpts {
            restarts: 1,
            seed: chain_seed(opts.seed, chain),
            ..opts.clone()
        };
        let mut local = |i: usize| scorer(i);
        // Nested under the worker's `pool.task` span when traced; the
        // lexical determinism rule stays satisfied because all timing
        // lives behind the macros.
        crate::trace_span!(
            "sa.chain",
            crate::time_span!("sa.chain_us", anneal(platform, &mut local, &chain_opts))
        )
    });

    let mut best_index = 0usize;
    let mut best_score = f64::NEG_INFINITY;
    let mut evaluations = 0usize;
    let mut trajectory = Vec::with_capacity(opts.steps * chains.len());
    for r in &results {
        evaluations += r.evaluations;
        // Strictly-greater: deterministic lowest-chain-id tiebreak.
        if r.best_score > best_score {
            best_score = r.best_score;
            best_index = r.best_index;
        }
        trajectory.extend_from_slice(&r.trajectory);
    }
    let mut running = f64::NEG_INFINITY;
    for t in trajectory.iter_mut() {
        running = running.max(*t);
        *t = running;
    }
    AnnealResult { best_index, best_score, evaluations, trajectory }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neighbors_stay_in_space_and_differ_in_exactly_one_knob() {
        let mut rng = Rng::new(1);
        for p in [PlatformId::Cpu, PlatformId::Spade, PlatformId::Gpu] {
            let n = space_size(p);
            let radix = radices(p);
            for _ in 0..200 {
                let i = rng.next_usize(n);
                let j = neighbor(p, i, &mut rng);
                assert!(j < n);
                assert_ne!(j, i, "{p:?}: neighbor returned the same index");
                // Compare mixed-radix digits: exactly one must differ.
                let (mut a, mut b, mut diffs) = (i, j, 0);
                for &r in radix.iter().rev() {
                    if a % r != b % r {
                        diffs += 1;
                    }
                    a /= r;
                    b /= r;
                }
                assert_eq!(diffs, 1, "{p:?}: {i} -> {j} changed {diffs} knobs");
            }
        }
    }

    #[test]
    fn anneal_finds_global_optimum_on_smooth_landscape() {
        // Score peaks at a specific config index; smooth in index space
        // is NOT guaranteed, so give SA a generous budget on SPADE (256).
        let target = 123usize;
        let mut calls = 0usize;
        let mut scorer = |i: usize| {
            calls += 1;
            -((i as f64 - target as f64).abs())
        };
        let r = anneal(
            PlatformId::Spade,
            &mut scorer,
            &AnnealOpts { steps: 400, restarts: 3, seed: 5, ..Default::default() },
        );
        // Must at least get close; exact hit is common with this budget.
        assert!(
            (r.best_index as i64 - target as i64).unsigned_abs() <= 8,
            "best {} target {target}",
            r.best_index
        );
        assert_eq!(r.evaluations, calls);
    }

    #[test]
    fn anneal_beats_random_sampling_at_equal_budget() {
        // Deterministic "cost" landscape with structure in the knobs.
        let space = crate::config::spade_space();
        let score_of = |i: usize| {
            let c = &space[i];
            let mut s = 0.0;
            s += if c.row_panels == 32 { 2.0 } else { 0.0 };
            s += if c.col_panels == 16384 { 2.0 } else { 0.0 };
            s += if c.barrier { 1.0 } else { 0.0 };
            s += if c.split == 256 { 0.5 } else { 0.0 };
            s - (c.bypass as u8 as f64) * 0.5
        };
        let budget = 80;
        let mut sa_scorer = score_of;
        let r = anneal(
            PlatformId::Spade,
            &mut sa_scorer,
            &AnnealOpts { steps: budget / 2, restarts: 2, seed: 3, ..Default::default() },
        );
        let mut rng = Rng::new(3);
        let mut rand_best = f64::NEG_INFINITY;
        for _ in 0..budget {
            rand_best = rand_best.max(score_of(rng.next_usize(space.len())));
        }
        assert!(
            r.best_score >= rand_best,
            "sa {} < random {rand_best}",
            r.best_score
        );
        // And SA should reach the actual optimum (5.5) here.
        assert!((r.best_score - 5.5).abs() < 1e-9, "best {}", r.best_score);
    }

    #[test]
    fn trajectory_monotone() {
        let mut scorer = |i: usize| (i % 17) as f64;
        let r = anneal(PlatformId::Gpu, &mut scorer, &AnnealOpts::default());
        for w in r.trajectory.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }

    #[test]
    fn par_anneal_thread_count_invariant() {
        // The merged result must not depend on how chains are scheduled.
        let scorer = |i: usize| -(((i as f64) - 100.0).abs());
        let opts = AnnealOpts { steps: 120, restarts: 4, seed: 9, ..Default::default() };
        let a = par_anneal(PlatformId::Spade, &scorer, &opts, 1);
        let b = par_anneal(PlatformId::Spade, &scorer, &opts, 8);
        assert_eq!(a.best_index, b.best_index);
        assert_eq!(a.best_score, b.best_score);
        assert_eq!(a.evaluations, b.evaluations);
        assert_eq!(a.trajectory, b.trajectory);
    }

    #[test]
    fn par_anneal_matches_sequential_chains() {
        // par_anneal == best over individually-run chains with the
        // derived seeds (the single-thread oracle).
        let scorer = |i: usize| ((i * 37) % 256) as f64;
        let opts = AnnealOpts { steps: 60, restarts: 3, seed: 4, ..Default::default() };
        let par = par_anneal(PlatformId::Spade, &scorer, &opts, 4);
        let mut best = f64::NEG_INFINITY;
        let mut best_idx = 0usize;
        let mut evals = 0usize;
        for chain in 0..opts.restarts as u64 {
            let mut local = |i: usize| scorer(i);
            let r = anneal(
                PlatformId::Spade,
                &mut local,
                &AnnealOpts { restarts: 1, seed: chain_seed(opts.seed, chain), ..opts.clone() },
            );
            evals += r.evaluations;
            if r.best_score > best {
                best = r.best_score;
                best_idx = r.best_index;
            }
        }
        assert_eq!(par.best_index, best_idx);
        assert_eq!(par.best_score, best);
        assert_eq!(par.evaluations, evals);
    }

    #[test]
    fn par_anneal_trajectory_monotone() {
        let scorer = |i: usize| (i % 23) as f64;
        let opts = AnnealOpts { restarts: 3, ..Default::default() };
        let r = par_anneal(PlatformId::Gpu, &scorer, &opts, 8);
        for w in r.trajectory.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }
}
