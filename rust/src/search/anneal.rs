//! Simulated-annealing configuration search.
//!
//! §2.3: "Finding the best configuration suggested by the cost model is
//! usually done using auxiliary intelligent search techniques such as
//! simulated annealing…". Our evaluation spaces (256–1,024 points) allow
//! exhaustive scoring, but the framework also ships the SA searcher so
//! unconstrained spaces (the paper's "computationally infeasible" full
//! SPADE space) can be explored with a bounded number of cost-model
//! queries. Neighbourhoods are single-knob mutations in the structured
//! config space.

use crate::config::{
    cpu_space, gpu_space, spade_space, Config, PlatformId, ALL_CPU_ORDERS, ALL_GPU_BINDINGS,
    CPU_I_SPLITS, CPU_J_SPLITS, CPU_K_SPLITS, GPU_I_SPLITS, GPU_K1_SPLITS, GPU_K2_SPLITS,
    GPU_UNROLLS, SPADE_COL_PANELS, SPADE_ROW_PANELS, SPADE_SPLITS,
};
use crate::sparse::reorder::ALL_REORDERS;
use crate::util::rng::Rng;

/// A scorer maps a config index to a predicted score (higher = faster).
pub trait Scorer {
    fn score(&mut self, idx: usize) -> f64;
}

impl<F: FnMut(usize) -> f64> Scorer for F {
    fn score(&mut self, idx: usize) -> f64 {
        self(idx)
    }
}

#[derive(Clone, Debug)]
pub struct AnnealOpts {
    pub steps: usize,
    pub t_start: f64,
    pub t_end: f64,
    pub seed: u64,
    /// Restarts from random points (best-of-all returned).
    pub restarts: usize,
}

impl Default for AnnealOpts {
    fn default() -> Self {
        Self { steps: 200, t_start: 1.0, t_end: 0.01, seed: 7, restarts: 2 }
    }
}

#[derive(Clone, Debug)]
pub struct AnnealResult {
    pub best_index: usize,
    pub best_score: f64,
    pub evaluations: usize,
    /// Best score after each step (for convergence plots).
    pub trajectory: Vec<f64>,
}

/// Single-knob neighbour in the enumerated space of `platform`.
/// Works on indices: decode → mutate one field → re-encode.
pub fn neighbor(platform: PlatformId, idx: usize, rng: &mut Rng) -> usize {
    match platform {
        PlatformId::Spade => {
            let space = spade_space();
            let mut c = space[idx];
            match rng.next_usize(6) {
                0 => c.row_panels = *rng.choose(&SPADE_ROW_PANELS),
                1 => c.col_panels = *rng.choose(&SPADE_COL_PANELS),
                2 => c.split = *rng.choose(&SPADE_SPLITS),
                3 => c.barrier = !c.barrier,
                4 => c.bypass = !c.bypass,
                _ => c.reorder = !c.reorder,
            }
            space.iter().position(|x| *x == c).unwrap()
        }
        PlatformId::Cpu => {
            let space = cpu_space();
            let mut c = space[idx];
            match rng.next_usize(5) {
                0 => c.i_split = *rng.choose(&CPU_I_SPLITS),
                1 => c.j_split = *rng.choose(&CPU_J_SPLITS),
                2 => c.k_split = *rng.choose(&CPU_K_SPLITS),
                3 => c.order = *rng.choose(&ALL_CPU_ORDERS),
                _ => c.format = *rng.choose(&ALL_REORDERS),
            }
            space.iter().position(|x| *x == c).unwrap()
        }
        PlatformId::Gpu => {
            let space = gpu_space();
            let mut c = space[idx];
            match rng.next_usize(6) {
                0 => c.i_split = *rng.choose(&GPU_I_SPLITS),
                1 => c.k1 = *rng.choose(&GPU_K1_SPLITS),
                2 => c.k2 = *rng.choose(&GPU_K2_SPLITS),
                3 => c.binding = *rng.choose(&ALL_GPU_BINDINGS),
                4 => c.unroll = *rng.choose(&GPU_UNROLLS),
                _ => c.vectorize = !c.vectorize,
            }
            space.iter().position(|x| *x == c).unwrap()
        }
    }
}

pub fn space_size(platform: PlatformId) -> usize {
    match platform {
        PlatformId::Cpu => cpu_space().len(),
        PlatformId::Spade => spade_space().len(),
        PlatformId::Gpu => gpu_space().len(),
    }
}

/// Maximise the scorer over the platform's config space.
pub fn anneal<S: Scorer>(platform: PlatformId, scorer: &mut S, opts: &AnnealOpts) -> AnnealResult {
    let n = space_size(platform);
    let mut rng = Rng::new(opts.seed);
    let mut best_index = 0usize;
    let mut best_score = f64::NEG_INFINITY;
    let mut evaluations = 0usize;
    let mut trajectory = Vec::with_capacity(opts.steps * opts.restarts.max(1));
    for restart in 0..opts.restarts.max(1) {
        let mut cur = rng.next_usize(n);
        let mut cur_score = scorer.score(cur);
        evaluations += 1;
        if cur_score > best_score {
            best_score = cur_score;
            best_index = cur;
        }
        for step in 0..opts.steps {
            let frac = step as f64 / opts.steps.max(1) as f64;
            let temp = opts.t_start * (opts.t_end / opts.t_start).powf(frac);
            let cand = neighbor(platform, cur, &mut rng.fork(restart as u64 * 1000 + step as u64));
            let cand_score = scorer.score(cand);
            evaluations += 1;
            let accept = cand_score >= cur_score
                || rng.next_f64() < ((cand_score - cur_score) / temp.max(1e-12)).exp();
            if accept {
                cur = cand;
                cur_score = cand_score;
            }
            if cur_score > best_score {
                best_score = cur_score;
                best_index = cur;
            }
            trajectory.push(best_score);
        }
    }
    AnnealResult { best_index, best_score, evaluations, trajectory }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neighbors_stay_in_space_and_differ_mostly() {
        let mut rng = Rng::new(1);
        for p in [PlatformId::Cpu, PlatformId::Spade, PlatformId::Gpu] {
            let n = space_size(p);
            let mut changed = 0;
            for _ in 0..100 {
                let i = rng.next_usize(n);
                let j = neighbor(p, i, &mut rng);
                assert!(j < n);
                if j != i {
                    changed += 1;
                }
            }
            // Re-drawing the same value for a knob is possible but rare.
            assert!(changed > 50, "{p:?}: only {changed} mutations changed the config");
        }
    }

    #[test]
    fn anneal_finds_global_optimum_on_smooth_landscape() {
        // Score peaks at a specific config index; smooth in index space
        // is NOT guaranteed, so give SA a generous budget on SPADE (256).
        let target = 123usize;
        let mut calls = 0usize;
        let mut scorer = |i: usize| {
            calls += 1;
            -((i as f64 - target as f64).abs())
        };
        let r = anneal(
            PlatformId::Spade,
            &mut scorer,
            &AnnealOpts { steps: 400, restarts: 3, seed: 5, ..Default::default() },
        );
        // Must at least get close; exact hit is common with this budget.
        assert!(
            (r.best_index as i64 - target as i64).unsigned_abs() <= 8,
            "best {} target {target}",
            r.best_index
        );
        assert_eq!(r.evaluations, calls);
    }

    #[test]
    fn anneal_beats_random_sampling_at_equal_budget() {
        // Deterministic "cost" landscape with structure in the knobs.
        let space = spade_space();
        let score_of = |i: usize| {
            let c = &space[i];
            let mut s = 0.0;
            s += if c.row_panels == 32 { 2.0 } else { 0.0 };
            s += if c.col_panels == 16384 { 2.0 } else { 0.0 };
            s += if c.barrier { 1.0 } else { 0.0 };
            s += if c.split == 256 { 0.5 } else { 0.0 };
            s - (c.bypass as u8 as f64) * 0.5
        };
        let budget = 80;
        let mut sa_scorer = score_of;
        let r = anneal(
            PlatformId::Spade,
            &mut sa_scorer,
            &AnnealOpts { steps: budget / 2, restarts: 2, seed: 3, ..Default::default() },
        );
        let mut rng = Rng::new(3);
        let mut rand_best = f64::NEG_INFINITY;
        for _ in 0..budget {
            rand_best = rand_best.max(score_of(rng.next_usize(space.len())));
        }
        assert!(
            r.best_score >= rand_best,
            "sa {} < random {rand_best}",
            r.best_score
        );
        // And SA should reach the actual optimum (5.5) here.
        assert!((r.best_score - 5.5).abs() < 1e-9, "best {}", r.best_score);
    }

    #[test]
    fn trajectory_monotone() {
        let mut scorer = |i: usize| (i % 17) as f64;
        let r = anneal(PlatformId::Gpu, &mut scorer, &AnnealOpts::default());
        for w in r.trajectory.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }
}
