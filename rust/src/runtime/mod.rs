//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only place the `xla` crate is touched. Artifacts are
//! described by `artifacts/manifest.json` (shapes/dtypes per entry
//! point); executables are compiled lazily on first use and cached, so
//! a process that only fine-tunes pays nothing for the 30+ other entry
//! points.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Typed host-side tensor passed to / returned from artifacts.
#[derive(Clone, Debug)]
pub enum Tensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Tensor {
    pub fn scalar_f32(x: f32) -> Tensor {
        Tensor::F32(vec![x], vec![])
    }
    pub fn scalar_i32(x: i32) -> Tensor {
        Tensor::I32(vec![x], vec![])
    }
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Tensor {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Tensor::F32(data, shape.to_vec())
    }
    pub fn as_f32(&self) -> &[f32] {
        match self {
            Tensor::F32(d, _) => d,
            _ => panic!("tensor is not f32"),
        }
    }
    pub fn into_f32(self) -> Vec<f32> {
        match self {
            Tensor::F32(d, _) => d,
            _ => panic!("tensor is not f32"),
        }
    }
    /// Borrowed view of this tensor (no data copy).
    pub fn view(&self) -> TensorView<'_> {
        match self {
            Tensor::F32(d, sh) => TensorView::F32(d, sh),
            Tensor::I32(d, sh) => TensorView::I32(d, sh),
        }
    }
}

/// Borrowed tensor input for `Runtime::exec_views`: lets hot paths
/// (batched scoring, featurization, train steps) pass `theta`, shared
/// embedding tiles, and reused staging buffers straight to PJRT without
/// cloning them into owned `Tensor`s per call.
#[derive(Clone, Copy, Debug)]
pub enum TensorView<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

impl<'a> TensorView<'a> {
    fn len_and_shape(&self) -> (usize, &'a [usize]) {
        match self {
            TensorView::F32(d, sh) => (d.len(), sh),
            TensorView::I32(d, sh) => (d.len(), sh),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            TensorView::F32(d, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
                if dims.is_empty() {
                    xla::Literal::scalar(d[0])
                } else {
                    xla::Literal::vec1(d).reshape(&dims)?
                }
            }
            TensorView::I32(d, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
                if dims.is_empty() {
                    xla::Literal::scalar(d[0])
                } else {
                    xla::Literal::vec1(d).reshape(&dims)?
                }
            }
        })
    }
}

pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub dims: HashMap<String, f64>,
    pub theta_len: HashMap<String, usize>,
    specs: HashMap<String, ArtifactSpec>,
    compiled: Mutex<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    /// Serialises EVERY touch of an xla-crate object (client, compiled
    /// executables, their literals-in-flight). See Send/Sync impls below.
    xla_lock: Mutex<()>,
}

// SAFETY: the `xla` crate wraps PJRT objects in `Rc` + raw pointers, so
// `Runtime` is not Send by construction. Ownership may still move
// between threads because every touch of the client or an executable
// (compile + execute + result fetch, all inside `exec`) runs while
// holding `xla_lock`, so the moving thread observes no xla object
// mid-operation and never clones an `Rc` concurrently with another
// thread. Host-side `Tensor`s are plain Vec<f32>.
unsafe impl Send for Runtime {}
// SAFETY: shared references are safe for the same reason as Send: all
// xla state is behind `xla_lock` (and `compiled` behind its own Mutex),
// so `&Runtime` from many threads serialises onto one PJRT call at a
// time — the CPU plugin is thread-safe for serialized calls from
// different threads. The remaining fields are read-only after `load`.
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Load the manifest and start a CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let manifest = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;

        let mut dims = HashMap::new();
        for (k, v) in manifest.req("dims").as_obj().context("dims")? {
            dims.insert(k.clone(), v.as_f64().context("dim value")?);
        }
        let mut theta_len = HashMap::new();
        for (k, v) in manifest.req("theta_len").as_obj().context("theta_len")? {
            theta_len.insert(k.clone(), v.as_usize().context("theta len")?);
        }
        let mut specs = HashMap::new();
        for (name, a) in manifest.req("artifacts").as_obj().context("artifacts")? {
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                a.req(key)
                    .as_arr()
                    .context("spec array")?
                    .iter()
                    .map(|s| {
                        Ok(TensorSpec {
                            name: s
                                .get("name")
                                .and_then(|n| n.as_str())
                                .unwrap_or("")
                                .to_string(),
                            shape: s
                                .req("shape")
                                .as_arr()
                                .context("shape")?
                                .iter()
                                .map(|d| d.as_usize().context("dim"))
                                .collect::<Result<_>>()?,
                            dtype: s.req("dtype").as_str().context("dtype")?.to_string(),
                        })
                    })
                    .collect()
            };
            specs.insert(
                name.clone(),
                ArtifactSpec {
                    file: a.req("file").as_str().context("file")?.to_string(),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                },
            );
        }
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            dims,
            theta_len,
            specs,
            compiled: Mutex::new(HashMap::new()),
            xla_lock: Mutex::new(()),
        })
    }

    pub fn dim(&self, key: &str) -> usize {
        *self
            .dims
            .get(key)
            .unwrap_or_else(|| panic!("manifest missing dim {key:?}")) as usize
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.specs.contains_key(name)
    }

    pub fn spec(&self, name: &str) -> &ArtifactSpec {
        self.specs
            .get(name)
            .unwrap_or_else(|| panic!("unknown artifact {name:?}"))
    }

    /// Must be called with `xla_lock` held.
    fn compile_locked(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.compiled.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .specs
            .get(name)
            .with_context(|| format!("unknown artifact {name:?}"))?;
        let path = self.dir.join(&spec.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?,
        );
        crate::debug!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        self.compiled.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact from owned tensors. Convenience wrapper over
    /// `exec_views`.
    pub fn exec(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let views: Vec<TensorView<'_>> = inputs.iter().map(Tensor::view).collect();
        self.exec_views(name, &views)
    }

    /// Execute an artifact from borrowed tensor views — the zero-copy
    /// entry point. Inputs are validated against the manifest.
    pub fn exec_views(&self, name: &str, inputs: &[TensorView<'_>]) -> Result<Vec<Tensor>> {
        let spec = self
            .specs
            .get(name)
            .with_context(|| format!("unknown artifact {name:?}"))?
            .clone();
        if inputs.len() != spec.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", spec.inputs.len(), inputs.len());
        }
        for (t, s) in inputs.iter().zip(&spec.inputs) {
            let (len, shape) = t.len_and_shape();
            if len != s.elems() || shape != s.shape.as_slice() {
                bail!(
                    "{name}: input {:?} shape mismatch: got {shape:?} want {:?}",
                    s.name,
                    s.shape
                );
            }
        }
        let _guard = self.xla_lock.lock().unwrap();
        let exe = self.compile_locked(name)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {name} result"))?;
        // aot.py lowers with return_tuple=True: single tuple output.
        let parts = result.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            bail!("{name}: expected {} outputs, got {}", spec.outputs.len(), parts.len());
        }
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, os)| {
                let data = lit
                    .to_vec::<f32>()
                    .with_context(|| format!("{name}: output to f32"))?;
                Ok(Tensor::F32(data, os.shape.clone()))
            })
            .collect()
    }
}

/// Default artifacts directory: `$COGNATE_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("COGNATE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Integration tests that need real artifacts live in rust/tests/;
    // here we test the manifest plumbing with a synthetic manifest.

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join("cognate_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"dims":{"FEAT_B":4},"theta_len":{"cognate":123},
                "artifacts":{"x_init":{"file":"x.hlo.txt",
                  "inputs":[{"name":"seed","shape":[],"dtype":"int32"}],
                  "outputs":[{"shape":[123],"dtype":"float32"}]}}}"#,
        )
        .unwrap();
        let rt = Runtime::load(&dir).unwrap();
        assert_eq!(rt.dim("FEAT_B"), 4);
        assert_eq!(rt.theta_len["cognate"], 123);
        assert!(rt.has_artifact("x_init"));
        assert!(!rt.has_artifact("nope"));
        let spec = rt.spec("x_init");
        assert_eq!(spec.inputs[0].shape, Vec::<usize>::new());
        assert_eq!(spec.outputs[0].elems(), 123);
        // Wrong input count rejected before any compile attempt.
        assert!(rt.exec("x_init", &[]).is_err());
    }

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::f32(vec![1.0; 6], &[2, 3]);
        assert_eq!(t.as_f32().len(), 6);
        let s = Tensor::scalar_f32(5.0);
        assert_eq!(s.as_f32(), &[5.0]);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        let _ = Tensor::f32(vec![1.0; 5], &[2, 3]);
    }

    #[test]
    fn tensor_view_borrows_without_copy() {
        let t = Tensor::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        match t.view() {
            TensorView::F32(d, sh) => {
                assert!(std::ptr::eq(d.as_ptr(), t.as_f32().as_ptr()));
                assert_eq!(sh, &[2, 2]);
            }
            _ => panic!("wrong view variant"),
        }
        let i = Tensor::scalar_i32(7);
        match i.view() {
            TensorView::I32(d, sh) => {
                assert_eq!(d, &[7]);
                assert!(sh.is_empty());
            }
            _ => panic!("wrong view variant"),
        }
    }
}
