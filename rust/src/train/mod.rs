//! Training and few-shot fine-tuning of the learned cost models
//! (pre-train on CPU → fine-tune on SPADE/GPU, §4.1).
//!
//! The actual gradient step runs inside the AOT `*_train` artifact
//! (loss + grad + Adam fused in one HLO); this module owns everything
//! around it: pair sampling, config-feature encoding, z-encoding of the
//! heterogeneous component, epoch loops and validation metrics
//! (PRL / OPA / Kendall-τ — Fig 6).

use crate::config::{self, Config, PlatformId};
use crate::dataset::Dataset;
use crate::model::pca::Pca;
use crate::model::{AeDriver, ModelDriver, TrainBatch};
use crate::util::rng::Rng;
use crate::util::stats;
use anyhow::Result;

/// Per-config feature tensors for one platform (row-major).
pub struct ConfigFeatures {
    pub n: usize,
    pub mapped: Vec<f32>, // [n, MAPPED_DIM]
    pub het: Vec<f32>,    // [n, HET_DIM]
    pub fa: Vec<f32>,     // [n, FA_DIM]
}

/// Encode every config of a platform. `cols` resolves SPADE's
/// NUM_MATRIX_COLS tiling option, so this is per-matrix for SPADE.
pub fn config_features(platform: PlatformId, cols: usize) -> ConfigFeatures {
    let configs: Vec<Config> = match platform {
        PlatformId::Cpu => config::cpu_space().iter().copied().map(Config::Cpu).collect(),
        PlatformId::Spade => config::spade_space().iter().copied().map(Config::Spade).collect(),
        PlatformId::Gpu => config::gpu_space().iter().copied().map(Config::Gpu).collect(),
    };
    let n = configs.len();
    let mut mapped = Vec::with_capacity(n * config::MAPPED_DIM);
    let mut het = Vec::with_capacity(n * config::HET_DIM);
    let mut fa = Vec::with_capacity(n * config::FA_DIM);
    for c in &configs {
        mapped.extend(config::mapped_vector(c, cols));
        het.extend(config::het_vector(c));
        fa.extend(config::fa_vector(c, cols));
    }
    ConfigFeatures { n, mapped, het, fa }
}

impl ConfigFeatures {
    /// The config vector a model variant consumes.
    pub fn cfg_for_variant<'a>(&'a self, variant: &str) -> (&'a [f32], usize) {
        if variant == "waco_fa" {
            (&self.fa, config::FA_DIM)
        } else {
            (&self.mapped, config::MAPPED_DIM)
        }
    }
}

/// How the heterogeneous component becomes the latent z (Fig 9).
pub enum ZEncoder {
    /// Trained autoencoder / VAE (the paper's choice).
    Ae(AeDriver),
    /// PCA projection (baseline).
    Pca(Pca),
    /// Raw het vector zero-padded to LATENT_DIM (feature augmentation).
    RawHet,
    /// All-zero latent (used by variants that ignore z).
    Zero,
}

impl ZEncoder {
    /// Encode [n, HET_DIM] het rows into [n, latent_dim] z rows.
    pub fn encode(&self, het: &[f32], het_dim: usize, latent_dim: usize) -> Result<Vec<f32>> {
        let n = het.len() / het_dim;
        Ok(match self {
            ZEncoder::Ae(ae) => ae.encode(het)?,
            ZEncoder::Pca(p) => p.encode(het, latent_dim),
            ZEncoder::RawHet => {
                let mut z = vec![0f32; n * latent_dim];
                for r in 0..n {
                    z[r * latent_dim..r * latent_dim + het_dim.min(latent_dim)]
                        .copy_from_slice(&het[r * het_dim..r * het_dim + het_dim.min(latent_dim)]);
                }
                z
            }
            ZEncoder::Zero => vec![0f32; n * latent_dim],
        })
    }
}

/// Train an autoencoder on a platform's het vectors (unsupervised,
/// §3.3: one AE per target platform / primitive pair).
pub fn train_autoencoder(
    ae: &mut AeDriver,
    het: &[f32],
    het_dim: usize,
    latent_dim: usize,
    steps: usize,
    batch: usize,
    seed: u64,
) -> Result<Vec<f32>> {
    let n = het.len() / het_dim;
    let mut rng = Rng::new(seed);
    let mut losses = Vec::with_capacity(steps);
    for _ in 0..steps {
        let mut x = vec![0f32; batch * het_dim];
        let mut eps = vec![0f32; batch * latent_dim];
        for r in 0..batch {
            let src = rng.next_usize(n);
            x[r * het_dim..(r + 1) * het_dim]
                .copy_from_slice(&het[src * het_dim..(src + 1) * het_dim]);
        }
        for e in eps.iter_mut() {
            *e = rng.next_gaussian() as f32;
        }
        losses.push(ae.train_step(&x, &eps)?);
    }
    Ok(losses)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FA_DIM, HET_DIM, MAPPED_DIM};

    #[test]
    fn config_features_sizes_per_platform() {
        for (p, n) in [
            (PlatformId::Cpu, 1024usize),
            (PlatformId::Spade, 256),
            (PlatformId::Gpu, 288),
        ] {
            let f = config_features(p, 4096);
            assert_eq!(f.n, n);
            assert_eq!(f.mapped.len(), n * MAPPED_DIM);
            assert_eq!(f.het.len(), n * HET_DIM);
            assert_eq!(f.fa.len(), n * FA_DIM);
        }
    }

    #[test]
    fn cfg_for_variant_selects_encoding() {
        let f = config_features(PlatformId::Spade, 1000);
        assert_eq!(f.cfg_for_variant("waco_fa").1, FA_DIM);
        assert_eq!(f.cfg_for_variant("waco_fm").1, MAPPED_DIM);
        assert_eq!(f.cfg_for_variant("cognate").1, MAPPED_DIM);
    }

    #[test]
    fn spade_mapped_features_depend_on_matrix_cols() {
        // NUM_MATRIX_COLS configs resolve differently per matrix width.
        let a = config_features(PlatformId::Spade, 1024);
        let b = config_features(PlatformId::Spade, 100_000);
        assert_ne!(a.mapped, b.mapped);
        assert_eq!(a.het, b.het, "het is matrix-independent");
    }

    #[test]
    fn zencoder_rawhet_pads_and_zero_zeroes() {
        let het = vec![1.0f32; 2 * 16];
        let raw = ZEncoder::RawHet.encode(&het, 16, 64).unwrap();
        assert_eq!(raw.len(), 2 * 64);
        assert_eq!(&raw[..16], &het[..16]);
        assert!(raw[16..64].iter().all(|&x| x == 0.0));
        let zero = ZEncoder::Zero.encode(&het, 16, 64).unwrap();
        assert!(zero.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn epoch_metrics_jsonl_appends_parseable_lines() {
        let path = std::env::temp_dir()
            .join(format!("cognate-epoch-metrics-{}", std::process::id()))
            .join("metrics_epochs.jsonl");
        let _ = std::fs::remove_file(&path);
        super::append_epoch_metrics(&path, "cognate", 0);
        super::append_epoch_metrics(&path, "cognate", 1);
        let text = std::fs::read_to_string(&path).expect("jsonl written");
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "one line per epoch");
        for (i, line) in lines.iter().enumerate() {
            let j = crate::util::json::Json::parse(line).expect("line parses");
            assert_eq!(j.req("epoch").as_usize(), Some(i));
            assert_eq!(j.req("variant").as_str(), Some("cognate"));
            assert!(j.req("metrics").get("counters").is_some(), "snapshot shape");
        }
        let _ = std::fs::remove_file(&path);
    }
}

#[derive(Clone, Debug)]
pub struct TrainOpts {
    pub epochs: usize,
    pub batches_per_epoch: usize,
    /// Configs sampled per matrix (the paper samples 100).
    pub configs_per_matrix: usize,
    pub seed: u64,
    /// Matrices used for per-epoch validation metrics (0 = skip).
    pub val_matrices: usize,
    /// Configs scored per validation matrix.
    pub val_configs: usize,
    pub log_every: usize,
    /// Append a per-epoch `Registry::snapshot()` JSON line here (one
    /// `{"epoch": N, "variant": ..., "metrics": {...}}` object per
    /// line), so experiment reruns can be diffed without rerunning.
    /// `None` = don't persist.
    pub metrics_jsonl: Option<std::path::PathBuf>,
}

impl Default for TrainOpts {
    fn default() -> Self {
        Self {
            epochs: 12,
            batches_per_epoch: 48,
            configs_per_matrix: 100,
            seed: 42,
            val_matrices: 8,
            val_configs: 48,
            log_every: 4,
            metrics_jsonl: None,
        }
    }
}

/// Append one epoch's telemetry snapshot to a JSONL file. Best-effort:
/// a persistence failure warns and never fails the training run.
fn append_epoch_metrics(path: &std::path::Path, variant: &str, epoch: usize) {
    use std::io::Write as _;
    let line = crate::util::json::Json::obj(vec![
        ("epoch", crate::util::json::Json::Num(epoch as f64)),
        ("variant", crate::util::json::Json::Str(variant.to_string())),
        ("metrics", crate::util::metrics::registry().snapshot()),
    ]);
    let res = (|| -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        writeln!(f, "{}", line.to_string())
    })();
    if let Err(e) = res {
        crate::warn!("failed to append epoch metrics to {}: {e}", path.display());
    }
}

#[derive(Clone, Debug)]
pub struct EpochLog {
    pub epoch: usize,
    pub train_loss: f64,
    pub val_prl: f64,
    pub val_opa: f64,
    pub val_ktau: f64,
}

/// Pre-train or fine-tune `driver` on `ds` restricted to `train_idx`.
/// The same routine serves both phases — fine-tuning is just a short
/// run on few matrices starting from pre-trained θ (§4.1).
pub fn train(
    driver: &mut ModelDriver,
    zenc: &ZEncoder,
    ds: &Dataset,
    train_idx: &[usize],
    val_idx: &[usize],
    opts: &TrainOpts,
) -> Result<Vec<EpochLog>> {
    assert!(!train_idx.is_empty(), "no training matrices");
    let rt = driver.runtime().clone();
    let (het_dim, latent_dim) = (rt.dim("HET_DIM"), rt.dim("LATENT_DIM"));
    let b = driver.train_b();
    let dmap_len = driver.dmap_len();
    let mut rng = Rng::new(opts.seed);
    let sampled = ds.sample_configs(opts.configs_per_matrix, opts.seed ^ 0x5EED);

    // Per-matrix cfg/z caches (SPADE's mapped vectors depend on cols).
    // het (→ z) is matrix-independent, so encode once.
    let feats0 = config_features(ds.platform, ds.records[0].cols);
    let z_all = zenc.encode(&feats0.het, het_dim, latent_dim)?;
    let cfg_dim = driver.cfg_dim;
    let per_matrix_cfg: Vec<Vec<f32>> = ds
        .records
        .iter()
        .map(|r| {
            let f = config_features(ds.platform, r.cols);
            f.cfg_for_variant(&driver.variant).0.to_vec()
        })
        .collect();

    let mut logs = Vec::with_capacity(opts.epochs);
    for epoch in 0..opts.epochs {
        let mut loss_sum = 0f64;
        for _ in 0..opts.batches_per_epoch {
            let t_sample = std::time::Instant::now();
            let mut batch = TrainBatch {
                dmap: vec![0f32; b * dmap_len],
                cfg_a: vec![0f32; b * cfg_dim],
                z_a: vec![0f32; b * latent_dim],
                cfg_b: vec![0f32; b * cfg_dim],
                z_b: vec![0f32; b * latent_dim],
                sign: vec![0f32; b],
                weight: vec![0f32; b],
            };
            for row in 0..b {
                let mi = train_idx[rng.next_usize(train_idx.len())];
                let rec = &ds.records[mi];
                let pool = &sampled[mi];
                let ca = pool[rng.next_usize(pool.len())] as usize;
                let mut cb = pool[rng.next_usize(pool.len())] as usize;
                let mut guard = 0;
                while rec.costs[cb] == rec.costs[ca] && guard < 8 {
                    cb = pool[rng.next_usize(pool.len())] as usize;
                    guard += 1;
                }
                batch.dmap[row * dmap_len..(row + 1) * dmap_len].copy_from_slice(&rec.dmap);
                let cfgs = &per_matrix_cfg[mi];
                batch.cfg_a[row * cfg_dim..(row + 1) * cfg_dim]
                    .copy_from_slice(&cfgs[ca * cfg_dim..(ca + 1) * cfg_dim]);
                batch.cfg_b[row * cfg_dim..(row + 1) * cfg_dim]
                    .copy_from_slice(&cfgs[cb * cfg_dim..(cb + 1) * cfg_dim]);
                batch.z_a[row * latent_dim..(row + 1) * latent_dim]
                    .copy_from_slice(&z_all[ca * latent_dim..(ca + 1) * latent_dim]);
                batch.z_b[row * latent_dim..(row + 1) * latent_dim]
                    .copy_from_slice(&z_all[cb * latent_dim..(cb + 1) * latent_dim]);
                // Higher score must mean faster config.
                let d = rec.costs[cb] - rec.costs[ca];
                batch.sign[row] = if d > 0.0 {
                    1.0
                } else if d < 0.0 {
                    -1.0
                } else {
                    0.0
                };
                batch.weight[row] = if d == 0.0 { 0.0 } else { 1.0 };
            }
            crate::histogram!("train.pair_sample_us").observe_duration(t_sample.elapsed());
            let step_loss = crate::trace_span!(
                "train.step",
                crate::time_span!("train.step_us", driver.train_step(&batch)?)
            );
            crate::counter!("train.steps_total").inc();
            loss_sum += step_loss as f64;
        }
        let train_loss = loss_sum / opts.batches_per_epoch as f64;
        crate::gauge!("train.loss").set(train_loss);

        // ---- validation ranking metrics --------------------------------
        let (mut prl, mut opa, mut ktau) = (f64::NAN, f64::NAN, f64::NAN);
        if opts.val_matrices > 0 && !val_idx.is_empty() {
            let mut prls = Vec::new();
            let mut opas = Vec::new();
            let mut ktaus = Vec::new();
            for &mi in val_idx.iter().take(opts.val_matrices) {
                let rec = &ds.records[mi];
                let mut vrng = rng.fork(mi as u64);
                let pick =
                    vrng.sample_indices(rec.costs.len(), opts.val_configs.min(rec.costs.len()));
                let s = driver.featurize(&[&rec.dmap])?.remove(0);
                let cfgs = &per_matrix_cfg[mi];
                let mut cfg_rows = Vec::with_capacity(pick.len() * cfg_dim);
                let mut z_rows = Vec::with_capacity(pick.len() * latent_dim);
                let mut truth = Vec::with_capacity(pick.len());
                for &ci in &pick {
                    cfg_rows.extend_from_slice(&cfgs[ci * cfg_dim..(ci + 1) * cfg_dim]);
                    z_rows.extend_from_slice(&z_all[ci * latent_dim..(ci + 1) * latent_dim]);
                    truth.push(rec.costs[ci]);
                }
                let scores = driver.score_configs(&s, &cfg_rows, &z_rows)?;
                prls.push(stats::pairwise_ranking_loss(&scores, &truth, 1.0));
                opas.push(stats::ordered_pair_accuracy(&scores.iter().map(|x| -x).collect::<Vec<_>>(), &truth));
                ktaus.push(stats::kendall_tau(&scores.iter().map(|x| -x).collect::<Vec<_>>(), &truth));
            }
            prl = stats::mean(&prls);
            opa = stats::mean(&opas);
            ktau = stats::mean(&ktaus);
            crate::gauge!("train.val_prl").set(prl);
            crate::gauge!("train.val_opa").set(opa);
            crate::gauge!("train.val_ktau").set(ktau);
        }
        if opts.log_every > 0 && epoch % opts.log_every == 0 {
            crate::info!(
                "[{}] epoch {epoch}: loss={train_loss:.4} prl={prl:.3} opa={opa:.3} ktau={ktau:.3}",
                driver.variant
            );
        }
        if let Some(path) = &opts.metrics_jsonl {
            append_epoch_metrics(path, &driver.variant, epoch);
        }
        logs.push(EpochLog { epoch, train_loss, val_prl: prl, val_opa: opa, val_ktau: ktau });
    }
    Ok(logs)
}
