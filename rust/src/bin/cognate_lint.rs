//! `cognate_lint` — the crate's invariant-enforcing static analysis
//! pass (see `util/lint`). Scans `rust/src`, `rust/benches`,
//! `rust/tests`, and `examples`, prints `file:line: rule: message`
//! diagnostics to stderr plus a machine-readable JSON summary, and
//! exits 1 on any finding (2 on IO/usage errors).
//!
//! ```text
//! cargo run --release --bin cognate_lint [-- --root PATH] [--json PATH]
//! ```

use cognate::util::lint::{discover_root, find_repo_root, lint_repo, ALL_RULES, SCAN_DIRS};
use std::path::{Path, PathBuf};

const HELP: &str = "cognate_lint: static analysis over the cognate crate

USAGE:
    cognate_lint [--root PATH] [--json PATH] [--quiet]

OPTIONS:
    --root PATH   repo root (default: $COGNATE_LINT_ROOT, else discovered
                  by walking up from the current directory)
    --json PATH   write the JSON summary to PATH instead of stdout
    --quiet       suppress per-finding diagnostics (JSON summary only)
    -h, --help    print this help

RULES:
    metric-canon, macro-instanced-aliasing, safety-comment, panic-audit,
    determinism, trace-canon — documented in ROADMAP.md §Static
    analysis. Suppress a single finding with
    `// lint:allow(<rule>) reason`; configure allowlists in lint.toml
    at the repo root.

EXIT CODES:
    0  no findings      1  findings reported      2  usage or IO error
";

struct Args {
    root: Option<PathBuf>,
    json_out: Option<PathBuf>,
    quiet: bool,
}

fn parse_args() -> Result<Option<Args>, String> {
    let mut args = Args { root: None, json_out: None, quiet: false };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "-h" | "--help" => return Ok(None),
            "--quiet" => args.quiet = true,
            "--root" => {
                let v = it.next().ok_or("--root needs a PATH")?;
                args.root = Some(PathBuf::from(v));
            }
            "--json" => {
                let v = it.next().ok_or("--json needs a PATH")?;
                args.json_out = Some(PathBuf::from(v));
            }
            other => return Err(format!("unknown argument {other:?} (see --help)")),
        }
    }
    Ok(Some(args))
}

fn main() {
    let args = match parse_args() {
        Ok(Some(a)) => a,
        Ok(None) => {
            print!("{HELP}");
            return;
        }
        Err(e) => {
            eprintln!("cognate_lint: {e}");
            std::process::exit(2);
        }
    };
    let root = match &args.root {
        Some(r) => find_repo_root(r).or_else(|| Some(r.clone())),
        None => discover_root(),
    };
    let Some(root) = root else {
        eprintln!(
            "cognate_lint: could not find the repo root (need rust/src + ROADMAP.md); \
             pass --root or set COGNATE_LINT_ROOT"
        );
        std::process::exit(2);
    };
    let report = match lint_repo(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("cognate_lint: scan failed under {}: {e}", root.display());
            std::process::exit(2);
        }
    };
    if !args.quiet {
        eprint!("{}", report.render());
    }
    let summary = report.to_json().to_string_pretty();
    match &args.json_out {
        Some(p) => {
            if let Err(e) = std::fs::write(p, &summary) {
                eprintln!("cognate_lint: cannot write {}: {e}", p.display());
                std::process::exit(2);
            }
        }
        None => print!("{summary}"),
    }
    if report.ok() {
        eprintln!(
            "cognate_lint: OK — {} files across {} clean under {} rules",
            report.files_scanned,
            SCAN_DIRS.join(", "),
            ALL_RULES.len()
        );
    } else {
        eprintln!(
            "cognate_lint: {} finding(s) in {} files scanned",
            report.findings.len(),
            report.files_scanned
        );
        std::process::exit(1);
    }
}

#[cfg(test)]
mod tests {
    // The binary is a thin shell over util::lint, which carries the
    // test weight (fixture self-tests + tests/lint.rs integration).
}
