//! Matrix (row) reordering strategies — SPADE's `matrix reordering`
//! knob and TACO's `format reordering` both resolve to one of these.

use super::csr::Csr;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Reorder {
    /// Identity (no reordering).
    None,
    /// Rows sorted by descending nnz — load balance for skewed matrices.
    DegreeDesc,
    /// Reverse Cuthill–McKee-style BFS ordering — bandwidth reduction.
    Rcm,
    /// Pseudo-random shuffle (a *bad* strategy, kept so learned models
    /// must discover it is bad — mirrors TACO's format-order freedom).
    Scatter,
}

pub const ALL_REORDERS: [Reorder; 4] =
    [Reorder::None, Reorder::DegreeDesc, Reorder::Rcm, Reorder::Scatter];

impl Reorder {
    pub fn name(&self) -> &'static str {
        match self {
            Reorder::None => "none",
            Reorder::DegreeDesc => "degree",
            Reorder::Rcm => "rcm",
            Reorder::Scatter => "scatter",
        }
    }
    pub fn index(&self) -> usize {
        match self {
            Reorder::None => 0,
            Reorder::DegreeDesc => 1,
            Reorder::Rcm => 2,
            Reorder::Scatter => 3,
        }
    }
}

/// Compute the row permutation for a strategy. `perm[new_row] = old_row`.
pub fn permutation(m: &Csr, strategy: Reorder) -> Vec<usize> {
    match strategy {
        Reorder::None => (0..m.rows).collect(),
        Reorder::DegreeDesc => {
            let mut idx: Vec<usize> = (0..m.rows).collect();
            // Stable sort keeps determinism for equal degrees.
            idx.sort_by_key(|&r| std::cmp::Reverse(m.row_len(r)));
            idx
        }
        Reorder::Rcm => rcm(m),
        Reorder::Scatter => {
            // Deterministic bit-mix shuffle (golden-ratio multiplicative
            // hash), independent of any RNG state.
            let mut idx: Vec<usize> = (0..m.rows).collect();
            idx.sort_by_key(|&r| (r as u64).wrapping_mul(0x9E3779B97F4A7C15));
            idx
        }
    }
}

/// Apply a strategy, returning the permuted matrix.
pub fn apply(m: &Csr, strategy: Reorder) -> Csr {
    match strategy {
        Reorder::None => m.clone(),
        _ => m.permute_rows(&permutation(m, strategy)),
    }
}

/// RCM-style ordering on the row-connectivity graph: rows are adjacent
/// if they share a column. Building that graph exactly is O(nnz²/cols)
/// in bad cases, so we use the standard trick of BFS over the bipartite
/// row→col→row relation, visiting neighbours in ascending-degree order,
/// then reversing. Works on rectangular matrices.
fn rcm(m: &Csr) -> Vec<usize> {
    let t = m.transpose();
    let mut visited = vec![false; m.rows];
    let mut order = Vec::with_capacity(m.rows);
    let mut degs: Vec<usize> = (0..m.rows).map(|r| m.row_len(r)).collect();
    // Process components from lowest-degree unvisited seed.
    let mut seeds: Vec<usize> = (0..m.rows).collect();
    seeds.sort_by_key(|&r| degs[r]);
    let mut queue = std::collections::VecDeque::new();
    for &seed in &seeds {
        if visited[seed] {
            continue;
        }
        visited[seed] = true;
        queue.push_back(seed);
        while let Some(r) = queue.pop_front() {
            order.push(r);
            // Neighbour rows via shared columns.
            let mut nbrs: Vec<usize> = Vec::new();
            for &c in m.row_indices(r) {
                for &r2 in t.row_indices(c as usize) {
                    let r2 = r2 as usize;
                    if !visited[r2] {
                        visited[r2] = true;
                        nbrs.push(r2);
                    }
                }
            }
            nbrs.sort_by_key(|&x| degs[x]);
            for n in nbrs {
                queue.push_back(n);
            }
        }
    }
    degs.clear();
    order.reverse();
    order
}

/// Matrix bandwidth: max |c - r| over nnz (square interpretation).
pub fn bandwidth(m: &Csr) -> usize {
    let mut bw = 0usize;
    for r in 0..m.rows {
        for &c in m.row_indices(r) {
            bw = bw.max((c as i64 - r as i64).unsigned_abs() as usize);
        }
    }
    bw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{generate, Family};

    #[test]
    fn permutations_are_valid() {
        let m = generate(Family::PowerLaw, 200, 200, 0.03, 1);
        for &s in &ALL_REORDERS {
            let p = permutation(&m, s);
            let mut sorted = p.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..m.rows).collect::<Vec<_>>(), "{s:?}");
            apply(&m, s).validate().unwrap();
        }
    }

    #[test]
    fn degree_sorts_descending() {
        let m = generate(Family::PowerLaw, 300, 300, 0.02, 2);
        let p = apply(&m, Reorder::DegreeDesc);
        let lens = p.row_lengths();
        for w in lens.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]
    fn rcm_improves_row_block_locality_of_shuffled_mesh() {
        // Row-only reordering cannot change column labels (so classic
        // bandwidth is out of its reach) — what it CAN do, and what the
        // tiling models reward, is make *consecutive rows share columns*:
        // the distinct-column working set per row block shrinks.
        let m = generate(Family::Mesh2d, 400, 400, 0.01, 3);
        let block_ucols_sum = |m: &Csr| -> usize {
            let mut ctr = crate::sparse::features::UniqueColCounter::new(m.cols);
            (0..m.rows)
                .step_by(32)
                .map(|r0| ctr.count(m, r0, r0 + 32))
                .sum()
        };
        let shuffled = apply(&m, Reorder::Scatter);
        let restored = apply(&shuffled, Reorder::Rcm);
        let u_shuffled = block_ucols_sum(&shuffled);
        let u_rcm = block_ucols_sum(&restored);
        assert!(
            u_rcm < u_shuffled,
            "rcm should shrink block working sets: {u_rcm} !< {u_shuffled}"
        );
    }

    #[test]
    fn nnz_preserved() {
        let m = generate(Family::Rmat, 128, 256, 0.02, 4);
        for &s in &ALL_REORDERS {
            assert_eq!(apply(&m, s).nnz(), m.nnz(), "{s:?}");
        }
    }
}
