//! Synthetic sparse-matrix collection — the SuiteSparse stand-in.
//!
//! The paper evaluates on 1,500 SuiteSparse matrices spanning many
//! domains. Offline we generate a seeded collection of matrices from six
//! structural families chosen to span the axes that make sparse-kernel
//! optima input-dependent: density, row-degree skew, bandedness /
//! locality, block structure, and aspect ratio. A `CollectionSpec`
//! reproduces the paper's five size bins (§4.1) at a configurable scale.

use super::csr::Csr;
use crate::util::rng::Rng;

/// Structural families. Each mimics a real SuiteSparse domain.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Family {
    /// Uniform Erdős–Rényi sparsity (e.g. random circuit matrices).
    Uniform,
    /// Power-law row degrees (social / web graphs).
    PowerLaw,
    /// RMAT/Kronecker-style self-similar graphs (graph analytics).
    Rmat,
    /// Banded diagonals (1-D PDE / time-series).
    Banded,
    /// Dense blocks on a sparse skeleton (multiphysics, FEM supernodes).
    Block,
    /// 5-point 2-D mesh stencil (structured PDE grids).
    Mesh2d,
}

pub const ALL_FAMILIES: [Family; 6] = [
    Family::Uniform,
    Family::PowerLaw,
    Family::Rmat,
    Family::Banded,
    Family::Block,
    Family::Mesh2d,
];

impl Family {
    pub fn name(&self) -> &'static str {
        match self {
            Family::Uniform => "uniform",
            Family::PowerLaw => "powerlaw",
            Family::Rmat => "rmat",
            Family::Banded => "banded",
            Family::Block => "block",
            Family::Mesh2d => "mesh2d",
        }
    }
}

/// A named matrix in the collection, with its generator provenance.
#[derive(Clone, Debug)]
pub struct MatrixInfo {
    pub name: String,
    pub family: Family,
    pub seed: u64,
    pub matrix: Csr,
}

/// Generate one matrix of the requested family / size / target density.
pub fn generate(family: Family, rows: usize, cols: usize, density: f64, seed: u64) -> Csr {
    let mut rng = Rng::new(seed ^ 0xC0C0_A7E5_EED5_EEDD);
    let target_nnz = ((rows as f64 * cols as f64 * density).round() as usize).max(rows.min(cols));
    let mut m = match family {
        Family::Uniform => gen_uniform(rows, cols, target_nnz, &mut rng),
        Family::PowerLaw => gen_powerlaw(rows, cols, target_nnz, &mut rng),
        Family::Rmat => gen_rmat(rows, cols, target_nnz, &mut rng),
        Family::Banded => gen_banded(rows, cols, target_nnz, &mut rng),
        Family::Block => gen_block(rows, cols, target_nnz, &mut rng),
        Family::Mesh2d => gen_mesh2d(rows, cols, &mut rng),
    };
    m.randomize_values(&mut rng);
    m
}

fn gen_uniform(rows: usize, cols: usize, nnz: usize, rng: &mut Rng) -> Csr {
    let mut coo = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        coo.push((rng.next_usize(rows) as u32, rng.next_usize(cols) as u32, 1.0));
    }
    Csr::from_coo(rows, cols, coo)
}

fn gen_powerlaw(rows: usize, cols: usize, nnz: usize, rng: &mut Rng) -> Csr {
    // Draw per-row degrees from a truncated Pareto, scale to hit nnz, then
    // scatter columns with mild locality (preferential low columns).
    let alpha = rng.range_f64(1.8, 2.6);
    let mut deg: Vec<f64> = (0..rows).map(|_| rng.next_powerlaw(alpha, cols as f64)).collect();
    let total: f64 = deg.iter().sum();
    let scale = nnz as f64 / total;
    let mut coo = Vec::with_capacity(nnz + rows);
    for (r, d) in deg.iter_mut().enumerate() {
        let k = ((*d * scale).round() as usize).clamp(1, cols);
        for _ in 0..k {
            // Zipf-ish column choice: square a uniform to bias low ids.
            let u = rng.next_f64();
            let c = ((u * u) * cols as f64) as usize % cols;
            coo.push((r as u32, c as u32, 1.0));
        }
    }
    Csr::from_coo(rows, cols, coo)
}

fn gen_rmat(rows: usize, cols: usize, nnz: usize, rng: &mut Rng) -> Csr {
    // Classic R-MAT recursion with (a, b, c, d) ≈ (0.57, 0.19, 0.19, 0.05).
    let (a, b, c) = (0.57, 0.19, 0.19);
    let rbits = (rows as f64).log2().ceil() as u32;
    let cbits = (cols as f64).log2().ceil() as u32;
    let mut coo = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        let (mut r, mut ccol) = (0usize, 0usize);
        for bit in 0..rbits.max(cbits) {
            let u = rng.next_f64();
            let (dr, dc) = if u < a {
                (0, 0)
            } else if u < a + b {
                (0, 1)
            } else if u < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            if bit < rbits {
                r = (r << 1) | dr;
            }
            if bit < cbits {
                ccol = (ccol << 1) | dc;
            }
        }
        coo.push(((r % rows) as u32, (ccol % cols) as u32, 1.0));
    }
    Csr::from_coo(rows, cols, coo)
}

fn gen_banded(rows: usize, cols: usize, nnz: usize, rng: &mut Rng) -> Csr {
    // Diagonal band with width sized from the nnz budget, plus light noise.
    let per_row = (nnz / rows.max(1)).max(1);
    let band = per_row.max(2);
    let mut coo = Vec::with_capacity(nnz + rows);
    let ratio = cols as f64 / rows.max(1) as f64;
    for r in 0..rows {
        let center = (r as f64 * ratio) as i64;
        for k in 0..per_row {
            let off = k as i64 - (band as i64) / 2 + (rng.next_usize(3) as i64 - 1);
            let c = (center + off).clamp(0, cols as i64 - 1);
            coo.push((r as u32, c as u32, 1.0));
        }
    }
    Csr::from_coo(rows, cols, coo)
}

fn gen_block(rows: usize, cols: usize, nnz: usize, rng: &mut Rng) -> Csr {
    // Random dense blocks until the budget is used.
    let bs = *rng.choose(&[4usize, 8, 16]);
    let mut coo = Vec::with_capacity(nnz + bs * bs);
    let mut placed = 0usize;
    while placed < nnz {
        let r0 = rng.next_usize(rows.saturating_sub(bs).max(1));
        let c0 = rng.next_usize(cols.saturating_sub(bs).max(1));
        let fill = rng.range_f64(0.6, 1.0);
        for dr in 0..bs.min(rows - r0) {
            for dc in 0..bs.min(cols - c0) {
                if rng.next_f64() < fill {
                    coo.push(((r0 + dr) as u32, (c0 + dc) as u32, 1.0));
                    placed += 1;
                }
            }
        }
    }
    Csr::from_coo(rows, cols, coo)
}

fn gen_mesh2d(rows: usize, cols: usize, rng: &mut Rng) -> Csr {
    // 5-point stencil over an s×s grid, s = floor(sqrt(min(rows, cols))),
    // embedded in a rows×cols matrix (square region), with a few random
    // long-range couplings to break perfect structure.
    let n = rows.min(cols);
    let s = (n as f64).sqrt() as usize;
    let n = s * s;
    let mut coo = Vec::with_capacity(5 * n);
    for y in 0..s {
        for x in 0..s {
            let i = (y * s + x) as u32;
            coo.push((i, i, 4.0));
            if x > 0 {
                coo.push((i, i - 1, -1.0));
            }
            if x + 1 < s {
                coo.push((i, i + 1, -1.0));
            }
            if y > 0 {
                coo.push((i, i - s as u32, -1.0));
            }
            if y + 1 < s {
                coo.push((i, i + s as u32, -1.0));
            }
        }
    }
    for _ in 0..n / 50 {
        coo.push((rng.next_usize(n) as u32, rng.next_usize(n) as u32, 0.5));
    }
    Csr::from_coo(rows, cols, coo)
}

/// Collection specification mirroring the paper's setup: five size bins
/// (§4.1: <8192 … >131072 total "input size" ≈ rows) sampled across all
/// families with varied densities.
#[derive(Clone, Debug)]
pub struct CollectionSpec {
    pub seed: u64,
    /// Matrices per (bin, family) cell.
    pub per_cell: usize,
    /// Upper bound on rows/cols, to scale the collection to the machine.
    pub max_dim: usize,
}

impl Default for CollectionSpec {
    fn default() -> Self {
        // ~6 families × 5 bins × 6 = 180 matrices, dims ≤ 4096: tractable
        // for full-pipeline runs on one machine. `--scale` raises this.
        Self { seed: 0xC0C0_A7E0, per_cell: 6, max_dim: 4096 }
    }
}

/// Paper's five size bins (by row count), clamped to `max_dim`.
pub fn size_bins(max_dim: usize) -> Vec<(usize, usize)> {
    let bins = [(256, 1024), (1024, 2048), (2048, 4096), (4096, 8192), (8192, 16384)];
    bins.iter()
        .map(|&(lo, hi)| (lo.min(max_dim), hi.min(max_dim)))
        .collect()
}

/// Generate the full named collection. Deterministic in `spec.seed`.
pub fn generate_collection(spec: &CollectionSpec) -> Vec<MatrixInfo> {
    let mut rng = Rng::new(spec.seed);
    let mut out = Vec::new();
    for (bin_idx, &(lo, hi)) in size_bins(spec.max_dim).iter().enumerate() {
        for &family in &ALL_FAMILIES {
            for k in 0..spec.per_cell {
                let mut r = rng.fork((bin_idx * 1000 + k) as u64 ^ family as u64);
                let rows = lo + r.next_usize((hi - lo).max(1));
                // Mix square and rectangular shapes.
                let cols = match r.next_usize(3) {
                    0 => rows,
                    1 => (rows / 2).max(64),
                    _ => (rows * 2).min(spec.max_dim.max(128)),
                };
                let density = 10f64.powf(r.range_f64(-3.2, -1.3));
                let seed = r.next_u64();
                let matrix = generate(family, rows, cols, density, seed);
                out.push(MatrixInfo {
                    name: format!("{}_{bin_idx}_{k}_{rows}x{cols}", family.name()),
                    family,
                    seed,
                    matrix,
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_families_generate_valid_matrices() {
        for &f in &ALL_FAMILIES {
            let m = generate(f, 200, 160, 0.02, 7);
            m.validate().unwrap_or_else(|e| panic!("{f:?}: {e}"));
            assert!(m.nnz() > 0, "{f:?} empty");
            assert_eq!(m.rows, 200);
            assert_eq!(m.cols, 160);
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = generate(Family::Rmat, 128, 128, 0.05, 42);
        let b = generate(Family::Rmat, 128, 128, 0.05, 42);
        let c = generate(Family::Rmat, 128, 128, 0.05, 43);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn powerlaw_rows_are_skewed() {
        let m = generate(Family::PowerLaw, 512, 512, 0.02, 1);
        let lens = m.row_lengths();
        let max = *lens.iter().max().unwrap() as f64;
        let mean = lens.iter().sum::<usize>() as f64 / lens.len() as f64;
        assert!(max > 4.0 * mean, "max={max} mean={mean}");
    }

    #[test]
    fn banded_is_local() {
        let m = generate(Family::Banded, 256, 256, 0.02, 3);
        // Every nnz within a small distance of the diagonal.
        for r in 0..m.rows {
            for &c in m.row_indices(r) {
                assert!((c as i64 - r as i64).unsigned_abs() < 32, "r={r} c={c}");
            }
        }
    }

    #[test]
    fn mesh_is_symmetric_structure() {
        let m = generate(Family::Mesh2d, 400, 400, 0.01, 5);
        assert!(m.nnz() >= 5 * 19 * 19); // s=20 grid minus borders
    }

    #[test]
    fn collection_covers_bins_and_families() {
        let spec = CollectionSpec { seed: 1, per_cell: 1, max_dim: 1024 };
        let coll = generate_collection(&spec);
        assert_eq!(coll.len(), 5 * ALL_FAMILIES.len());
        for info in &coll {
            info.matrix.validate().unwrap();
            assert!(info.matrix.rows <= 1024);
        }
        // Names unique.
        let mut names: Vec<&str> = coll.iter().map(|i| i.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), coll.len());
    }

    #[test]
    fn collection_deterministic() {
        let spec = CollectionSpec { seed: 9, per_cell: 1, max_dim: 512 };
        let a = generate_collection(&spec);
        let b = generate_collection(&spec);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.matrix, y.matrix);
        }
    }
}
