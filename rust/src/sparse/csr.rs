//! Compressed Sparse Row matrices — the core data structure every layer
//! of the system consumes: the executable kernels, the platform cost
//! models, the featurizer, and the generators.

use crate::util::rng::Rng;

/// CSR sparse matrix with `f32` values.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// Row pointers, length `rows + 1`.
    pub indptr: Vec<usize>,
    /// Column indices, sorted within each row, length `nnz`.
    pub indices: Vec<u32>,
    /// Values, length `nnz`.
    pub values: Vec<f32>,
}

impl Csr {
    /// Build from COO triplets. Duplicate (r, c) entries are summed,
    /// column indices are sorted within each row.
    pub fn from_coo(rows: usize, cols: usize, mut coo: Vec<(u32, u32, f32)>) -> Csr {
        coo.sort_unstable_by_key(|&(r, c, _)| ((r as u64) << 32) | c as u64);
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(coo.len());
        let mut values: Vec<f32> = Vec::with_capacity(coo.len());
        for (r, c, v) in coo {
            debug_assert!((r as usize) < rows && (c as usize) < cols);
            if let (Some(&last_c), true) = (indices.last(), indptr[r as usize + 1] > 0) {
                // same row (because sorted) and same column ⇒ accumulate
                if last_c == c && indices.len() > indptr[r as usize] {
                    *values.last_mut().unwrap() += v;
                    continue;
                }
            }
            // close out any rows between the previous entry and this one
            indices.push(c);
            values.push(v);
            indptr[r as usize + 1] += 1;
        }
        for r in 0..rows {
            indptr[r + 1] += indptr[r];
        }
        let m = Csr { rows, cols, indptr, indices, values };
        debug_assert!(m.validate().is_ok(), "{:?}", m.validate());
        m
    }

    /// An empty matrix of the given shape.
    pub fn empty(rows: usize, cols: usize) -> Csr {
        Csr { rows, cols, indptr: vec![0; rows + 1], indices: vec![], values: vec![] }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// Column indices of row `r`.
    pub fn row_indices(&self, r: usize) -> &[u32] {
        &self.indices[self.indptr[r]..self.indptr[r + 1]]
    }

    pub fn row_values(&self, r: usize) -> &[f32] {
        &self.values[self.indptr[r]..self.indptr[r + 1]]
    }

    pub fn row_len(&self, r: usize) -> usize {
        self.indptr[r + 1] - self.indptr[r]
    }

    /// Structural integrity check (sorted unique columns, monotone indptr).
    pub fn validate(&self) -> Result<(), String> {
        if self.indptr.len() != self.rows + 1 {
            return Err("indptr length".into());
        }
        if self.indptr[0] != 0 || *self.indptr.last().unwrap() != self.indices.len() {
            return Err("indptr bounds".into());
        }
        if self.indices.len() != self.values.len() {
            return Err("indices/values length mismatch".into());
        }
        // Bounds/monotonicity first — row_indices() slices would panic on
        // corrupt indptr otherwise.
        for r in 0..self.rows {
            if self.indptr[r] > self.indptr[r + 1] || self.indptr[r + 1] > self.indices.len() {
                return Err(format!("indptr not monotone/in-bounds at row {r}"));
            }
        }
        for r in 0..self.rows {
            let idx = self.row_indices(r);
            for w in idx.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("row {r} columns not sorted/unique"));
                }
            }
            if let Some(&last) = idx.last() {
                if last as usize >= self.cols {
                    return Err(format!("row {r} column out of bounds"));
                }
            }
        }
        Ok(())
    }

    /// Transpose (CSR of Aᵀ) via counting sort — O(nnz + rows + cols).
    pub fn transpose(&self) -> Csr {
        let mut indptr = vec![0usize; self.cols + 1];
        for &c in &self.indices {
            indptr[c as usize + 1] += 1;
        }
        for c in 0..self.cols {
            indptr[c + 1] += indptr[c];
        }
        let mut indices = vec![0u32; self.nnz()];
        let mut values = vec![0f32; self.nnz()];
        let mut cursor = indptr.clone();
        for r in 0..self.rows {
            for (&c, &v) in self.row_indices(r).iter().zip(self.row_values(r)) {
                let dst = cursor[c as usize];
                indices[dst] = r as u32;
                values[dst] = v;
                cursor[c as usize] += 1;
            }
        }
        Csr { rows: self.cols, cols: self.rows, indptr, indices, values }
    }

    /// Apply a row permutation: row `r` of the result is row `perm[r]`
    /// of `self`. Column structure is untouched.
    pub fn permute_rows(&self, perm: &[usize]) -> Csr {
        assert_eq!(perm.len(), self.rows);
        let mut indptr = vec![0usize; self.rows + 1];
        for (new_r, &old_r) in perm.iter().enumerate() {
            indptr[new_r + 1] = indptr[new_r] + self.row_len(old_r);
        }
        let mut indices = Vec::with_capacity(self.nnz());
        let mut values = Vec::with_capacity(self.nnz());
        for &old_r in perm {
            indices.extend_from_slice(self.row_indices(old_r));
            values.extend_from_slice(self.row_values(old_r));
        }
        Csr { rows: self.rows, cols: self.cols, indptr, indices, values }
    }

    /// Per-row nnz counts.
    pub fn row_lengths(&self) -> Vec<usize> {
        (0..self.rows).map(|r| self.row_len(r)).collect()
    }

    /// Fill values with uniform randoms in [-1, 1] (structure unchanged);
    /// used to make numeric kernel tests non-trivial.
    pub fn randomize_values(&mut self, rng: &mut Rng) {
        for v in &mut self.values {
            *v = (rng.next_f64() * 2.0 - 1.0) as f32;
        }
    }

    /// Dense row-major representation (tests only; small matrices).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut d = vec![0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for (&c, &v) in self.row_indices(r).iter().zip(self.row_values(r)) {
                d[r * self.cols + c as usize] = v;
            }
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [[1, 0, 2],
        //  [0, 0, 0],
        //  [3, 4, 0]]
        Csr::from_coo(3, 3, vec![(0, 0, 1.0), (0, 2, 2.0), (2, 0, 3.0), (2, 1, 4.0)])
    }

    #[test]
    fn from_coo_sorted_and_valid() {
        let m = Csr::from_coo(3, 3, vec![(2, 1, 4.0), (0, 2, 2.0), (2, 0, 3.0), (0, 0, 1.0)]);
        assert_eq!(m, sample());
        m.validate().unwrap();
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.row_indices(2), &[0, 1]);
        assert_eq!(m.row_len(1), 0);
    }

    #[test]
    fn duplicates_summed() {
        let m = Csr::from_coo(2, 2, vec![(0, 0, 1.0), (0, 0, 2.5), (1, 1, 1.0)]);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.row_values(0), &[3.5]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = sample();
        let t = m.transpose();
        t.validate().unwrap();
        assert_eq!(t.rows, 3);
        assert_eq!(t.row_indices(0), &[0, 2]); // col 0 had rows 0 and 2
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn transpose_matches_dense() {
        let m = sample();
        let t = m.transpose();
        let d = m.to_dense();
        let dt = t.to_dense();
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(d[r * 3 + c], dt[c * 3 + r]);
            }
        }
    }

    #[test]
    fn permute_rows_valid() {
        let m = sample();
        let p = m.permute_rows(&[2, 0, 1]);
        p.validate().unwrap();
        assert_eq!(p.row_indices(0), m.row_indices(2));
        assert_eq!(p.row_values(1), m.row_values(0));
        assert_eq!(p.nnz(), m.nnz());
    }

    #[test]
    fn density_and_empty() {
        assert!((sample().density() - 4.0 / 9.0).abs() < 1e-12);
        let e = Csr::empty(4, 5);
        e.validate().unwrap();
        assert_eq!(e.nnz(), 0);
        assert_eq!(e.density(), 0.0);
    }

    #[test]
    fn validate_catches_corruption() {
        let mut m = sample();
        m.indices[0] = 99; // out of bounds
        assert!(m.validate().is_err());
        let mut m2 = sample();
        m2.indptr[1] = 5; // beyond nnz of row 0 region ordering
        assert!(m2.validate().is_err());
    }
}
