//! Sparsity-pattern featurization.
//!
//! The paper's input featurizer consumes raw (row, col) coordinates with
//! a submanifold sparse CNN. Our hardware adaptation (DESIGN.md
//! §Hardware-Adaptation) rasterises the pattern into a fixed
//! `C × H × W` *density map* consumed by a dense conv pyramid lowered to
//! Pallas matmuls. Channels:
//!   0: nnz count per cell, normalised by the max cell count
//!   1: log1p(count) / log1p(max) — compresses dynamic range
//!   2: row-profile (fraction of the row's nnz landing in this cell col)
//!   3: col-profile (fraction of the col's nnz landing in this cell row)
//! Plus scalar summary features used by host-side baselines and reports.

use super::csr::Csr;

/// Density-map resolution — must match `python/compile/dims.py`
/// (`DMAP_C/H/W`); checked at runtime against artifacts/manifest.json.
pub const DMAP_C: usize = 4;
pub const DMAP_H: usize = 32;
pub const DMAP_W: usize = 32;
pub const DMAP_LEN: usize = DMAP_C * DMAP_H * DMAP_W;

/// Rasterise the sparsity pattern into the fixed density map (CHW, f32).
pub fn density_map(m: &Csr) -> Vec<f32> {
    let mut counts = vec![0f32; DMAP_H * DMAP_W];
    let mut row_tot = vec![0f32; DMAP_H];
    let mut col_tot = vec![0f32; DMAP_W];
    let rscale = DMAP_H as f64 / m.rows.max(1) as f64;
    let cscale = DMAP_W as f64 / m.cols.max(1) as f64;
    for r in 0..m.rows {
        let br = ((r as f64 * rscale) as usize).min(DMAP_H - 1);
        for &c in m.row_indices(r) {
            let bc = ((c as f64 * cscale) as usize).min(DMAP_W - 1);
            counts[br * DMAP_W + bc] += 1.0;
            row_tot[br] += 1.0;
            col_tot[bc] += 1.0;
        }
    }
    let maxc = counts.iter().cloned().fold(0f32, f32::max).max(1.0);
    let mut out = vec![0f32; DMAP_LEN];
    let (ch0, rest) = out.split_at_mut(DMAP_H * DMAP_W);
    let (ch1, rest) = rest.split_at_mut(DMAP_H * DMAP_W);
    let (ch2, ch3) = rest.split_at_mut(DMAP_H * DMAP_W);
    for i in 0..DMAP_H * DMAP_W {
        let c = counts[i];
        ch0[i] = c / maxc;
        ch1[i] = (1.0 + c).ln() / (1.0 + maxc).ln();
        let r = i / DMAP_W;
        let col = i % DMAP_W;
        ch2[i] = if row_tot[r] > 0.0 { c / row_tot[r] } else { 0.0 };
        ch3[i] = if col_tot[col] > 0.0 { c / col_tot[col] } else { 0.0 };
    }
    out
}

/// Scalar summary statistics of a sparsity pattern. Used by the
/// platform cost models and as cheap host-side features.
#[derive(Clone, Copy, Debug, Default)]
pub struct MatrixStats {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    pub density: f64,
    pub row_mean: f64,
    pub row_cv: f64,   // coefficient of variation of row lengths
    pub row_max: usize,
    /// Mean |col − row·(cols/rows)| distance from the main diagonal,
    /// normalised by cols: 0 = perfectly banded, ~0.25 = uniform.
    pub bandedness: f64,
    /// Mean per-row column gap (locality of accesses within a row),
    /// normalised by cols.
    pub mean_col_gap: f64,
}

pub fn matrix_stats(m: &Csr) -> MatrixStats {
    let nnz = m.nnz();
    let lens = m.row_lengths();
    let mean = nnz as f64 / m.rows.max(1) as f64;
    let var = lens
        .iter()
        .map(|&l| (l as f64 - mean) * (l as f64 - mean))
        .sum::<f64>()
        / m.rows.max(1) as f64;
    let cv = if mean > 0.0 { var.sqrt() / mean } else { 0.0 };
    let ratio = m.cols as f64 / m.rows.max(1) as f64;
    let mut diag_dist = 0f64;
    let mut gap_sum = 0f64;
    let mut gap_n = 0usize;
    for r in 0..m.rows {
        let idx = m.row_indices(r);
        let center = r as f64 * ratio;
        for &c in idx {
            diag_dist += (c as f64 - center).abs();
        }
        for w in idx.windows(2) {
            gap_sum += (w[1] - w[0]) as f64;
            gap_n += 1;
        }
    }
    MatrixStats {
        rows: m.rows,
        cols: m.cols,
        nnz,
        density: m.density(),
        row_mean: mean,
        row_cv: cv,
        row_max: lens.iter().copied().max().unwrap_or(0),
        bandedness: if nnz > 0 { diag_dist / nnz as f64 / m.cols.max(1) as f64 } else { 0.0 },
        mean_col_gap: if gap_n > 0 { gap_sum / gap_n as f64 / m.cols.max(1) as f64 } else { 0.0 },
    }
}

/// Number of *distinct* columns touched by a contiguous row block — the
/// quantity that determines dense-operand reuse for SpMM tiling decisions
/// in both the CPU cache model and the SPADE buffer model. Cost
/// O(block nnz) using a stamp array shared across calls.
pub struct UniqueColCounter {
    stamp: Vec<u32>,
    epoch: u32,
}

impl UniqueColCounter {
    pub fn new(cols: usize) -> Self {
        Self { stamp: vec![0; cols], epoch: 0 }
    }

    pub fn count(&mut self, m: &Csr, row_start: usize, row_end: usize) -> usize {
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            self.stamp.fill(0);
            self.epoch = 1;
        }
        let mut uniq = 0usize;
        for r in row_start..row_end.min(m.rows) {
            for &c in m.row_indices(r) {
                let s = &mut self.stamp[c as usize];
                if *s != self.epoch {
                    *s = self.epoch;
                    uniq += 1;
                }
            }
        }
        uniq
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{generate, Family};

    #[test]
    fn density_map_shape_and_range() {
        let m = generate(Family::Uniform, 300, 200, 0.02, 1);
        let d = density_map(&m);
        assert_eq!(d.len(), DMAP_LEN);
        for &v in &d {
            assert!((0.0..=1.0001).contains(&v), "v={v}");
        }
        // channel 0 max is exactly 1 (normalised by max cell)
        let ch0max = d[..DMAP_H * DMAP_W].iter().cloned().fold(0f32, f32::max);
        assert!((ch0max - 1.0).abs() < 1e-6);
    }

    #[test]
    fn density_map_distinguishes_families() {
        let banded = density_map(&generate(Family::Banded, 512, 512, 0.01, 2));
        let uniform = density_map(&generate(Family::Uniform, 512, 512, 0.01, 2));
        let l1: f32 = banded.iter().zip(&uniform).map(|(a, b)| (a - b).abs()).sum();
        assert!(l1 > 10.0, "maps too similar: {l1}");
    }

    #[test]
    fn empty_matrix_map_is_zero() {
        let m = Csr::empty(10, 10);
        assert!(density_map(&m).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn stats_banded_vs_uniform() {
        let b = matrix_stats(&generate(Family::Banded, 512, 512, 0.01, 3));
        let u = matrix_stats(&generate(Family::Uniform, 512, 512, 0.01, 3));
        assert!(b.bandedness < 0.05, "banded bandedness={}", b.bandedness);
        assert!(u.bandedness > 0.15, "uniform bandedness={}", u.bandedness);
        assert!(b.mean_col_gap < u.mean_col_gap);
    }

    #[test]
    fn stats_powerlaw_high_cv() {
        let p = matrix_stats(&generate(Family::PowerLaw, 512, 512, 0.02, 4));
        let u = matrix_stats(&generate(Family::Uniform, 512, 512, 0.02, 4));
        assert!(p.row_cv > 2.0 * u.row_cv, "p.cv={} u.cv={}", p.row_cv, u.row_cv);
    }

    #[test]
    fn unique_cols_counter() {
        let m = Csr::from_coo(
            4,
            8,
            vec![(0, 1, 1.0), (0, 3, 1.0), (1, 1, 1.0), (1, 5, 1.0), (2, 1, 1.0), (3, 7, 1.0)],
        );
        let mut ctr = UniqueColCounter::new(8);
        assert_eq!(ctr.count(&m, 0, 2), 3); // {1,3,5}
        assert_eq!(ctr.count(&m, 0, 4), 4); // {1,3,5,7}
        assert_eq!(ctr.count(&m, 2, 3), 1);
        assert_eq!(ctr.count(&m, 4, 9), 0); // clamped past end
    }
}
