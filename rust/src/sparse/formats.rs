//! Alternative sparse storage formats and conversions.
//!
//! TACO's format space (the source platform's programming system)
//! includes per-dimension dense/compressed layouts; the executable
//! substrate keeps CSR as its working format but ships faithful
//! conversions — CSC (column-major), COO and BSR (blocked rows, the
//! layout SPADE-like accelerators stream) — all round-trip-tested.

use super::csr::Csr;

/// Compressed Sparse Column.
#[derive(Clone, Debug, PartialEq)]
pub struct Csc {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>, // per column
    pub indices: Vec<u32>,  // row ids, sorted in each column
    pub values: Vec<f32>,
}

/// Block Sparse Row with `B×B` dense blocks (zero-padded).
#[derive(Clone, Debug, PartialEq)]
pub struct Bsr {
    pub rows: usize,
    pub cols: usize,
    pub block: usize,
    pub indptr: Vec<usize>,  // per block-row
    pub indices: Vec<u32>,   // block-column ids
    pub values: Vec<f32>,    // len = nnz_blocks * block * block
}

pub fn csr_to_csc(m: &Csr) -> Csc {
    let t = m.transpose();
    Csc { rows: m.rows, cols: m.cols, indptr: t.indptr, indices: t.indices, values: t.values }
}

pub fn csc_to_csr(c: &Csc) -> Csr {
    let as_csr = Csr {
        rows: c.cols,
        cols: c.rows,
        indptr: c.indptr.clone(),
        indices: c.indices.clone(),
        values: c.values.clone(),
    };
    as_csr.transpose()
}

pub fn csr_to_coo(m: &Csr) -> Vec<(u32, u32, f32)> {
    let mut coo = Vec::with_capacity(m.nnz());
    for r in 0..m.rows {
        for (&c, &v) in m.row_indices(r).iter().zip(m.row_values(r)) {
            coo.push((r as u32, c, v));
        }
    }
    coo
}

pub fn csr_to_bsr(m: &Csr, block: usize) -> Bsr {
    assert!(block > 0);
    let brows = m.rows.div_ceil(block);
    let bcols = m.cols.div_ceil(block);
    let mut indptr = vec![0usize; brows + 1];
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<f32> = Vec::new();
    // Per block-row: find occupied block-columns, then fill.
    let mut stamp = vec![usize::MAX; bcols];
    let mut order: Vec<u32> = Vec::new();
    for br in 0..brows {
        order.clear();
        let r0 = br * block;
        let r1 = ((br + 1) * block).min(m.rows);
        for r in r0..r1 {
            for &c in m.row_indices(r) {
                let bc = c as usize / block;
                if stamp[bc] != br {
                    stamp[bc] = br;
                    order.push(bc as u32);
                }
            }
        }
        order.sort_unstable();
        let base_block = indices.len();
        indices.extend_from_slice(&order);
        values.resize(values.len() + order.len() * block * block, 0.0);
        // Fill block values.
        for r in r0..r1 {
            for (&c, &v) in m.row_indices(r).iter().zip(m.row_values(r)) {
                let bc = (c as usize / block) as u32;
                let slot = base_block
                    + indices[base_block..].binary_search(&bc).unwrap();
                let off = slot * block * block + (r - r0) * block + (c as usize % block);
                values[off] = v;
            }
        }
        indptr[br + 1] = indices.len();
    }
    Bsr { rows: m.rows, cols: m.cols, block, indptr, indices, values }
}

pub fn bsr_to_csr(b: &Bsr) -> Csr {
    let mut coo = Vec::new();
    let bs = b.block;
    for br in 0..(b.indptr.len() - 1) {
        for slot in b.indptr[br]..b.indptr[br + 1] {
            let bc = b.indices[slot] as usize;
            for dr in 0..bs {
                let r = br * bs + dr;
                if r >= b.rows {
                    break;
                }
                for dc in 0..bs {
                    let c = bc * bs + dc;
                    if c >= b.cols {
                        break;
                    }
                    let v = b.values[slot * bs * bs + dr * bs + dc];
                    if v != 0.0 {
                        coo.push((r as u32, c as u32, v));
                    }
                }
            }
        }
    }
    Csr::from_coo(b.rows, b.cols, coo)
}

impl Bsr {
    pub fn nnz_blocks(&self) -> usize {
        self.indices.len()
    }
    /// Fraction of stored block slots that hold actual nonzeros —
    /// the fill efficiency metric block formats trade on.
    pub fn fill_ratio(&self, original_nnz: usize) -> f64 {
        if self.nnz_blocks() == 0 {
            return 1.0;
        }
        original_nnz as f64 / (self.nnz_blocks() * self.block * self.block) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{generate, Family, ALL_FAMILIES};

    #[test]
    fn csc_roundtrip_all_families() {
        for &f in &ALL_FAMILIES {
            let m = generate(f, 150, 120, 0.03, 7);
            let back = csc_to_csr(&csr_to_csc(&m));
            assert_eq!(back, m, "{f:?}");
        }
    }

    #[test]
    fn coo_roundtrip() {
        let m = generate(Family::Rmat, 90, 140, 0.04, 3);
        let back = Csr::from_coo(m.rows, m.cols, csr_to_coo(&m));
        assert_eq!(back, m);
    }

    #[test]
    fn bsr_roundtrip_various_blocks() {
        let m = generate(Family::Block, 130, 130, 0.05, 5);
        for &bs in &[2usize, 4, 8, 16] {
            let b = csr_to_bsr(&m, bs);
            let back = bsr_to_csr(&b);
            assert_eq!(back.indices, m.indices, "block {bs}");
            assert_eq!(back.indptr, m.indptr);
            for (x, y) in back.values.iter().zip(&m.values) {
                assert!((x - y).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn bsr_fill_ratio_reflects_structure() {
        // Block-structured matrices pack blocks much better than uniform.
        let blocky = generate(Family::Block, 256, 256, 0.05, 1);
        let uniform = generate(Family::Uniform, 256, 256, 0.05, 1);
        let fb = csr_to_bsr(&blocky, 4).fill_ratio(blocky.nnz());
        let fu = csr_to_bsr(&uniform, 4).fill_ratio(uniform.nnz());
        assert!(fb > 1.8 * fu, "block fill {fb} vs uniform {fu}");
        assert!(fb <= 1.0 + 1e-9);
    }

    #[test]
    fn bsr_handles_ragged_edges() {
        // Dims not divisible by the block size.
        let m = generate(Family::Banded, 101, 77, 0.05, 9);
        let b = csr_to_bsr(&m, 8);
        assert_eq!(bsr_to_csr(&b).nnz(), m.nnz());
    }

    #[test]
    fn csc_column_access_matches_transpose_semantics() {
        let m = generate(Family::PowerLaw, 64, 64, 0.05, 2);
        let c = csr_to_csc(&m);
        // Column j of m = rows listed in csc.indices[indptr[j]..indptr[j+1]]
        let dense = m.to_dense();
        for j in 0..m.cols {
            let col_rows: Vec<u32> = c.indices[c.indptr[j]..c.indptr[j + 1]].to_vec();
            for r in 0..m.rows {
                let expected_nz = dense[r * m.cols + j] != 0.0;
                assert_eq!(col_rows.contains(&(r as u32)), expected_nz);
            }
        }
    }
}
