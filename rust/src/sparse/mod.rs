//! Sparse-matrix substrate: CSR storage, the synthetic SuiteSparse-like
//! collection generator, featurization (density maps + summary stats),
//! row reordering strategies, and MatrixMarket I/O.

pub mod csr;
pub mod features;
pub mod formats;
pub mod gen;
pub mod mm;
pub mod reorder;

pub use csr::Csr;
pub use gen::{generate, generate_collection, CollectionSpec, Family, MatrixInfo};
