//! MatrixMarket (`.mtx`) I/O — lets real SuiteSparse matrices be dropped
//! into the pipeline in place of the synthetic collection when available.
//! Supports `matrix coordinate {real,integer,pattern} {general,symmetric}`.

use super::csr::Csr;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

pub fn read_mtx(path: &Path) -> Result<Csr> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    parse_mtx(BufReader::new(file))
}

pub fn parse_mtx<R: BufRead>(reader: R) -> Result<Csr> {
    let mut lines = reader.lines();
    let header = lines
        .next()
        .context("empty file")?
        .context("read header")?;
    let h: Vec<String> = header.split_whitespace().map(|s| s.to_lowercase()).collect();
    if h.len() < 4 || h[0] != "%%matrixmarket" || h[1] != "matrix" {
        bail!("not a MatrixMarket header: {header:?}");
    }
    if h[2] != "coordinate" {
        bail!("only coordinate format supported, got {:?}", h[2]);
    }
    let field = h[3].as_str();
    if !matches!(field, "real" | "integer" | "pattern") {
        bail!("unsupported field {field:?}");
    }
    let symmetry = h.get(4).map(String::as_str).unwrap_or("general").to_string();
    if !matches!(symmetry.as_str(), "general" | "symmetric") {
        bail!("unsupported symmetry {symmetry:?}");
    }

    // Skip comments; first non-comment line is the size line.
    let mut size_line = None;
    for line in lines.by_ref() {
        let line = line.context("read line")?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = Some(line);
        break;
    }
    let size_line = size_line.context("missing size line")?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| t.parse::<usize>())
        .collect::<std::result::Result<_, _>>()
        .with_context(|| format!("bad size line {size_line:?}"))?;
    if dims.len() != 3 {
        bail!("size line needs 3 fields");
    }
    let (rows, cols, nnz) = (dims[0], dims[1], dims[2]);

    let mut coo: Vec<(u32, u32, f32)> = Vec::with_capacity(nnz);
    let mut seen = 0usize;
    for line in lines {
        let line = line.context("read entry")?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let r: usize = it.next().context("row")?.parse().context("row parse")?;
        let c: usize = it.next().context("col")?.parse().context("col parse")?;
        let v: f32 = if field == "pattern" {
            1.0
        } else {
            it.next().context("value")?.parse().context("value parse")?
        };
        if r == 0 || c == 0 || r > rows || c > cols {
            bail!("entry out of bounds: {r} {c}");
        }
        coo.push((r as u32 - 1, c as u32 - 1, v));
        if symmetry == "symmetric" && r != c {
            coo.push((c as u32 - 1, r as u32 - 1, v));
        }
        seen += 1;
    }
    if seen != nnz {
        bail!("declared nnz {nnz} but found {seen}");
    }
    Ok(Csr::from_coo(rows, cols, coo))
}

pub fn write_mtx(path: &Path, m: &Csr) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(f, "% written by cognate-repro")?;
    writeln!(f, "{} {} {}", m.rows, m.cols, m.nnz())?;
    for r in 0..m.rows {
        for (&c, &v) in m.row_indices(r).iter().zip(m.row_values(r)) {
            writeln!(f, "{} {} {}", r + 1, c + 1, v)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_general_real() {
        let src = "%%MatrixMarket matrix coordinate real general\n% comment\n3 3 2\n1 1 1.5\n3 2 -2\n";
        let m = parse_mtx(Cursor::new(src)).unwrap();
        assert_eq!(m.rows, 3);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.row_values(0), &[1.5]);
        assert_eq!(m.row_indices(2), &[1]);
    }

    #[test]
    fn parse_symmetric_pattern() {
        let src = "%%MatrixMarket matrix coordinate pattern symmetric\n3 3 2\n2 1\n3 3\n";
        let m = parse_mtx(Cursor::new(src)).unwrap();
        // (2,1) mirrored to (1,2); diagonal (3,3) not duplicated.
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.row_indices(0), &[1]);
        assert_eq!(m.row_indices(1), &[0]);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse_mtx(Cursor::new("garbage")).is_err());
        assert!(parse_mtx(Cursor::new("%%MatrixMarket matrix array real general\n2 2\n")).is_err());
        let oob = "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n";
        assert!(parse_mtx(Cursor::new(oob)).is_err());
        let wrong_nnz = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n";
        assert!(parse_mtx(Cursor::new(wrong_nnz)).is_err());
    }

    #[test]
    fn roundtrip_via_tempfile() {
        let m = crate::sparse::gen::generate(crate::sparse::gen::Family::Rmat, 64, 48, 0.05, 7);
        let dir = std::env::temp_dir().join("cognate_mm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.mtx");
        write_mtx(&path, &m).unwrap();
        let back = read_mtx(&path).unwrap();
        assert_eq!(back.rows, m.rows);
        assert_eq!(back.cols, m.cols);
        assert_eq!(back.nnz(), m.nnz());
        assert_eq!(back.indices, m.indices);
        for (a, b) in back.values.iter().zip(&m.values) {
            assert!((a - b).abs() < 1e-5);
        }
        std::fs::remove_file(&path).ok();
    }
}
