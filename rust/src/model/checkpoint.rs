//! Model checkpointing: persist/restore a trained cost model's flat θ
//! (plus optimiser state and provenance) so the CLI can split the
//! pipeline across invocations (`pretrain` → file → `finetune` → file →
//! `eval`/`serve`), exactly how the artifact would ship.
//!
//! Format: small self-describing little-endian binary, `.ckpt`.

use super::ModelDriver;
use crate::runtime::Runtime;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;
use std::sync::Arc;

const MAGIC: &[u8; 8] = b"COGCKPT1";

#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub variant: String,
    /// Free-form provenance (platform/op/epochs), recorded for humans.
    pub note: String,
    pub step: u64,
    pub theta: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

impl Checkpoint {
    pub fn from_driver(d: &ModelDriver, note: &str) -> Checkpoint {
        Checkpoint {
            variant: d.variant.clone(),
            note: note.to_string(),
            step: d.step,
            theta: d.theta.clone(),
            m: d.m.clone(),
            v: d.v.clone(),
        }
    }

    /// Restore into a fresh driver (validates θ length vs the manifest).
    pub fn into_driver(self, rt: Arc<Runtime>) -> Result<ModelDriver> {
        let expect = *rt
            .theta_len
            .get(&self.variant)
            .with_context(|| format!("manifest lacks variant {:?}", self.variant))?;
        if self.theta.len() != expect {
            bail!(
                "checkpoint θ length {} != manifest {} — artifacts changed since saving?",
                self.theta.len(),
                expect
            );
        }
        let mut d = ModelDriver::init(rt, &self.variant, 0)?;
        d.theta = self.theta;
        d.m = self.m;
        d.v = self.v;
        d.step = self.step;
        Ok(d)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        for s in [&self.variant, &self.note] {
            let b = s.as_bytes();
            w.write_all(&(b.len() as u32).to_le_bytes())?;
            w.write_all(b)?;
        }
        w.write_all(&self.step.to_le_bytes())?;
        for buf in [&self.theta, &self.m, &self.v] {
            w.write_all(&(buf.len() as u64).to_le_bytes())?;
            for &f in buf.iter() {
                w.write_all(&f.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut r = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a cognate checkpoint: {path:?}");
        }
        let mut read_str = |r: &mut dyn Read| -> Result<String> {
            let mut lb = [0u8; 4];
            r.read_exact(&mut lb)?;
            let mut b = vec![0u8; u32::from_le_bytes(lb) as usize];
            r.read_exact(&mut b)?;
            Ok(String::from_utf8(b)?)
        };
        let variant = read_str(&mut r)?;
        let note = read_str(&mut r)?;
        let mut sb = [0u8; 8];
        r.read_exact(&mut sb)?;
        let step = u64::from_le_bytes(sb);
        let mut read_f32s = |r: &mut dyn Read| -> Result<Vec<f32>> {
            let mut lb = [0u8; 8];
            r.read_exact(&mut lb)?;
            let n = u64::from_le_bytes(lb) as usize;
            let mut out = vec![0f32; n];
            let mut fb = [0u8; 4];
            for v in &mut out {
                r.read_exact(&mut fb)?;
                *v = f32::from_le_bytes(fb);
            }
            Ok(out)
        };
        let theta = read_f32s(&mut r)?;
        let m = read_f32s(&mut r)?;
        let v = read_f32s(&mut r)?;
        if m.len() != theta.len() || v.len() != theta.len() {
            bail!("checkpoint buffer lengths disagree");
        }
        Ok(Checkpoint { variant, note, step, theta, m, v })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake(variant: &str, n: usize) -> Checkpoint {
        Checkpoint {
            variant: variant.into(),
            note: "unit-test".into(),
            step: 42,
            theta: (0..n).map(|i| i as f32 * 0.5).collect(),
            m: vec![0.1; n],
            v: vec![0.2; n],
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("cognate_ckpt_test");
        let path = dir.join("a.ckpt");
        let c = fake("cognate", 1000);
        c.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.variant, c.variant);
        assert_eq!(back.note, c.note);
        assert_eq!(back.step, c.step);
        assert_eq!(back.theta, c.theta);
        assert_eq!(back.m, c.m);
        assert_eq!(back.v, c.v);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage_and_truncation() {
        let dir = std::env::temp_dir().join("cognate_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("bad.ckpt");
        std::fs::write(&bad, b"COGCKPT1 but truncated").unwrap();
        assert!(Checkpoint::load(&bad).is_err());
        std::fs::write(&bad, b"NOTMAGIC").unwrap();
        assert!(Checkpoint::load(&bad).is_err());
        std::fs::remove_file(&bad).ok();
    }
}
