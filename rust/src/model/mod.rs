//! Host-side cost-model driver: owns the flat parameter buffers (θ,
//! Adam m/v) and drives the AOT train/featurize/score entry points
//! through the PJRT runtime. One driver instance = one model variant
//! being trained or served.

pub mod checkpoint;
pub mod pca;

use crate::runtime::{Runtime, Tensor, TensorView};
use anyhow::{Context, Result};
use std::sync::Arc;

/// A batch of ranking pairs, already encoded (see `train::encode`).
/// All vectors are flattened row-major at the manifest's TRAIN_B batch.
#[derive(Clone, Debug, Default)]
pub struct TrainBatch {
    pub dmap: Vec<f32>,  // [B, C, H, W]
    pub cfg_a: Vec<f32>, // [B, cfg_dim]
    pub z_a: Vec<f32>,   // [B, LATENT]
    pub cfg_b: Vec<f32>,
    pub z_b: Vec<f32>,
    pub sign: Vec<f32>,   // [B]
    pub weight: Vec<f32>, // [B] (0 ⇒ padded row)
}

pub struct ModelDriver {
    rt: Arc<Runtime>,
    pub variant: String,
    pub theta: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: u64,
    pub cfg_dim: usize,
}

impl ModelDriver {
    /// Initialise fresh parameters via the `{variant}_init` artifact.
    pub fn init(rt: Arc<Runtime>, variant: &str, seed: i32) -> Result<ModelDriver> {
        let theta_len = *rt
            .theta_len
            .get(variant)
            .with_context(|| format!("unknown variant {variant:?}"))?;
        let out = rt.exec(&format!("{variant}_init"), &[Tensor::scalar_i32(seed)])?;
        let theta = out.into_iter().next().context("init output")?.into_f32();
        anyhow::ensure!(theta.len() == theta_len, "theta length mismatch");
        let cfg_dim = if variant == "waco_fa" { rt.dim("FA_DIM") } else { rt.dim("MAPPED_DIM") };
        Ok(ModelDriver {
            rt,
            variant: variant.to_string(),
            m: vec![0.0; theta_len],
            v: vec![0.0; theta_len],
            theta,
            step: 0,
            cfg_dim,
        })
    }

    /// Clone parameters into a new driver (e.g. pre-trained → fine-tune),
    /// resetting the optimiser state as the paper's fine-tuning does.
    pub fn fork_for_finetune(&self) -> ModelDriver {
        ModelDriver {
            rt: self.rt.clone(),
            variant: self.variant.clone(),
            theta: self.theta.clone(),
            m: vec![0.0; self.theta.len()],
            v: vec![0.0; self.theta.len()],
            step: 0,
            cfg_dim: self.cfg_dim,
        }
    }

    /// Split one trained driver into `n ≥ 1` serving replicas. The
    /// runtime `Arc` is shared (PJRT executions already serialise on
    /// the runtime's internal lock); θ is cloned per replica so each
    /// shard batcher owns its parameters without synchronisation.
    /// Optimiser state is dropped — replicas only run the featurize /
    /// score entry points, and `train_step` on a replica fails its
    /// shape check cleanly rather than training on empty m/v.
    pub fn replicate(self, n: usize) -> Vec<ModelDriver> {
        let n = n.max(1);
        let mut out = Vec::with_capacity(n);
        for _ in 1..n {
            out.push(ModelDriver {
                rt: self.rt.clone(),
                variant: self.variant.clone(),
                theta: self.theta.clone(),
                m: Vec::new(),
                v: Vec::new(),
                step: self.step,
                cfg_dim: self.cfg_dim,
            });
        }
        out.push(ModelDriver { m: Vec::new(), v: Vec::new(), ..self });
        out
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    pub fn train_b(&self) -> usize {
        self.rt.dim("TRAIN_B")
    }
    pub fn feat_b(&self) -> usize {
        self.rt.dim("FEAT_B")
    }
    pub fn score_b(&self) -> usize {
        self.rt.dim("SCORE_B")
    }
    pub fn embed_dim(&self) -> usize {
        self.rt.dim("EMBED_DIM")
    }
    pub fn latent_dim(&self) -> usize {
        self.rt.dim("LATENT_DIM")
    }
    pub fn dmap_len(&self) -> usize {
        self.rt.dim("DMAP_C") * self.rt.dim("DMAP_H") * self.rt.dim("DMAP_W")
    }

    /// One Adam step on a batch of pairs; returns the batch loss.
    pub fn train_step(&mut self, batch: &TrainBatch) -> Result<f32> {
        let b = self.train_b();
        anyhow::ensure!(batch.sign.len() == b, "batch size {} != TRAIN_B {b}", batch.sign.len());
        let (c, h, w) =
            (self.rt.dim("DMAP_C"), self.rt.dim("DMAP_H"), self.rt.dim("DMAP_W"));
        self.step += 1;
        let lat = self.latent_dim();
        let tl = self.theta.len();
        // Borrowed views: nothing is cloned into the runtime call — the
        // seed implementation copied θ/m/v and every batch vector here.
        let step = [self.step as f32];
        let out = self.rt.exec_views(
            &format!("{}_train", self.variant),
            &[
                TensorView::F32(&self.theta, &[tl]),
                TensorView::F32(&self.m, &[tl]),
                TensorView::F32(&self.v, &[tl]),
                TensorView::F32(&step, &[]),
                TensorView::F32(&batch.dmap, &[b, c, h, w]),
                TensorView::F32(&batch.cfg_a, &[b, self.cfg_dim]),
                TensorView::F32(&batch.z_a, &[b, lat]),
                TensorView::F32(&batch.cfg_b, &[b, self.cfg_dim]),
                TensorView::F32(&batch.z_b, &[b, lat]),
                TensorView::F32(&batch.sign, &[b]),
                TensorView::F32(&batch.weight, &[b]),
            ],
        )?;
        let mut it = out.into_iter();
        self.theta = it.next().context("theta out")?.into_f32();
        self.m = it.next().context("m out")?.into_f32();
        self.v = it.next().context("v out")?.into_f32();
        let loss = it.next().context("loss out")?.into_f32()[0];
        Ok(loss)
    }

    /// Matrix embeddings for a set of density maps (padded to FEAT_B).
    pub fn featurize(&self, dmaps: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let fb = self.feat_b();
        let dl = self.dmap_len();
        let ed = self.embed_dim();
        let (c, h, w) =
            (self.rt.dim("DMAP_C"), self.rt.dim("DMAP_H"), self.rt.dim("DMAP_W"));
        let name = format!("{}_featurize", self.variant);
        let tl = self.theta.len();
        let mut out = Vec::with_capacity(dmaps.len());
        // One staging buffer reused across chunks; θ passed by view.
        let mut buf = vec![0f32; fb * dl];
        for chunk in dmaps.chunks(fb) {
            for (i, d) in chunk.iter().enumerate() {
                anyhow::ensure!(d.len() == dl, "density map length");
                buf[i * dl..(i + 1) * dl].copy_from_slice(d);
            }
            buf[chunk.len() * dl..].fill(0.0);
            let res = self.rt.exec_views(
                &name,
                &[
                    TensorView::F32(&self.theta, &[tl]),
                    TensorView::F32(&buf, &[fb, c, h, w]),
                ],
            )?;
            let s = res.into_iter().next().context("featurize out")?.into_f32();
            for i in 0..chunk.len() {
                out.push(s[i * ed..(i + 1) * ed].to_vec());
            }
        }
        Ok(out)
    }

    /// Score many configs of ONE matrix given its cached embedding.
    /// `cfgs` / `zs` are row-major [n, cfg_dim] / [n, LATENT].
    pub fn score_configs(&self, s_embed: &[f32], cfgs: &[f32], zs: &[f32]) -> Result<Vec<f64>> {
        let sb = self.score_b();
        let ed = self.embed_dim();
        let lat = self.latent_dim();
        anyhow::ensure!(s_embed.len() == ed, "embedding length");
        let n = cfgs.len() / self.cfg_dim;
        anyhow::ensure!(zs.len() == n * lat, "z rows");
        let name = format!("{}_score_cached", self.variant);
        let tl = self.theta.len();
        let mut scores = Vec::with_capacity(n);
        // The replicated embedding tile is built once and passed by view
        // to every chunk (the seed cloned it, θ, and fresh cfg/z staging
        // buffers per chunk). Staging buffers are reused with zeroed
        // tails for the final partial chunk.
        let mut s_tile = vec![0f32; sb * ed];
        for row in 0..sb {
            s_tile[row * ed..(row + 1) * ed].copy_from_slice(s_embed);
        }
        let mut cbuf = vec![0f32; sb * self.cfg_dim];
        let mut zbuf = vec![0f32; sb * lat];
        let mut start = 0usize;
        while start < n {
            let count = (n - start).min(sb);
            cbuf[..count * self.cfg_dim]
                .copy_from_slice(&cfgs[start * self.cfg_dim..(start + count) * self.cfg_dim]);
            cbuf[count * self.cfg_dim..].fill(0.0);
            zbuf[..count * lat].copy_from_slice(&zs[start * lat..(start + count) * lat]);
            zbuf[count * lat..].fill(0.0);
            let res = self.rt.exec_views(
                &name,
                &[
                    TensorView::F32(&self.theta, &[tl]),
                    TensorView::F32(&s_tile, &[sb, ed]),
                    TensorView::F32(&cbuf, &[sb, self.cfg_dim]),
                    TensorView::F32(&zbuf, &[sb, lat]),
                ],
            )?;
            let r = res.into_iter().next().context("score out")?.into_f32();
            scores.extend(r[..count].iter().map(|&x| x as f64));
            start += count;
        }
        Ok(scores)
    }
}

/// Autoencoder driver (latent encoder of §3.3).
pub struct AeDriver {
    rt: Arc<Runtime>,
    pub kind: String,
    pub theta: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    step: u64,
}

impl AeDriver {
    pub fn init(rt: Arc<Runtime>, kind: &str, seed: i32) -> Result<AeDriver> {
        let theta_len = *rt.theta_len.get(kind).with_context(|| format!("ae kind {kind:?}"))?;
        let out = rt.exec(&format!("{kind}_init"), &[Tensor::scalar_i32(seed)])?;
        let theta = out.into_iter().next().context("ae init")?.into_f32();
        anyhow::ensure!(theta.len() == theta_len);
        Ok(AeDriver {
            rt,
            kind: kind.to_string(),
            m: vec![0.0; theta_len],
            v: vec![0.0; theta_len],
            theta,
            step: 0,
        })
    }

    /// One unsupervised step on a batch of het vectors [SCORE_B, HET_DIM].
    pub fn train_step(&mut self, x: &[f32], eps: &[f32]) -> Result<f32> {
        let b = self.rt.dim("SCORE_B");
        let hd = self.rt.dim("HET_DIM");
        let lat = self.rt.dim("LATENT_DIM");
        anyhow::ensure!(x.len() == b * hd, "ae batch shape");
        anyhow::ensure!(eps.len() == b * lat, "ae eps shape");
        self.step += 1;
        let tl = self.theta.len();
        let step = [self.step as f32];
        let out = self.rt.exec_views(
            &format!("{}_train", self.kind),
            &[
                TensorView::F32(&self.theta, &[tl]),
                TensorView::F32(&self.m, &[tl]),
                TensorView::F32(&self.v, &[tl]),
                TensorView::F32(&step, &[]),
                TensorView::F32(x, &[b, hd]),
                TensorView::F32(eps, &[b, lat]),
            ],
        )?;
        let mut it = out.into_iter();
        self.theta = it.next().context("ae theta")?.into_f32();
        self.m = it.next().context("ae m")?.into_f32();
        self.v = it.next().context("ae v")?.into_f32();
        Ok(it.next().context("ae loss")?.into_f32()[0])
    }

    /// Encode het vectors → latent z, in SCORE_B chunks with padding.
    pub fn encode(&self, x: &[f32]) -> Result<Vec<f32>> {
        let b = self.rt.dim("SCORE_B");
        let hd = self.rt.dim("HET_DIM");
        let lat = self.rt.dim("LATENT_DIM");
        let n = x.len() / hd;
        let name = format!("{}_encode", self.kind);
        let tl = self.theta.len();
        let mut out = Vec::with_capacity(n * lat);
        let mut buf = vec![0f32; b * hd];
        let mut start = 0;
        while start < n {
            let count = (n - start).min(b);
            buf[..count * hd].copy_from_slice(&x[start * hd..(start + count) * hd]);
            buf[count * hd..].fill(0.0);
            let res = self.rt.exec_views(
                &name,
                &[TensorView::F32(&self.theta, &[tl]), TensorView::F32(&buf, &[b, hd])],
            )?;
            let z = res.into_iter().next().context("ae encode")?.into_f32();
            out.extend_from_slice(&z[..count * lat]);
            start += count;
        }
        Ok(out)
    }
}
