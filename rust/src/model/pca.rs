//! Host-side PCA encoder — the Fig 9 baseline for representing the
//! heterogeneous configuration component (vs. the autoencoder).
//!
//! Classical PCA on the het vectors: covariance → Jacobi eigensolver
//! (the het dimension is 16, so an O(d³)-per-sweep dense solver is
//! instant) → project onto the top components → zero-pad to LATENT_DIM
//! so the output is drop-in compatible with the z-input of the model.

/// Symmetric Jacobi eigendecomposition: returns (eigenvalues,
/// eigenvectors-as-rows), sorted by descending eigenvalue.
pub fn jacobi_eigen(a: &[f64], d: usize, sweeps: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a.len(), d * d);
    let mut m = a.to_vec();
    let mut v = vec![0f64; d * d];
    for i in 0..d {
        v[i * d + i] = 1.0;
    }
    for _ in 0..sweeps {
        let mut off = 0.0;
        for p in 0..d {
            for q in (p + 1)..d {
                off += m[p * d + q] * m[p * d + q];
            }
        }
        if off < 1e-22 {
            break;
        }
        for p in 0..d {
            for q in (p + 1)..d {
                let apq = m[p * d + q];
                if apq.abs() < 1e-18 {
                    continue;
                }
                let app = m[p * d + p];
                let aqq = m[q * d + q];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for k in 0..d {
                    let mkp = m[k * d + p];
                    let mkq = m[k * d + q];
                    m[k * d + p] = c * mkp - s * mkq;
                    m[k * d + q] = s * mkp + c * mkq;
                }
                for k in 0..d {
                    let mpk = m[p * d + k];
                    let mqk = m[q * d + k];
                    m[p * d + k] = c * mpk - s * mqk;
                    m[q * d + k] = s * mpk + c * mqk;
                }
                for k in 0..d {
                    let vkp = v[k * d + p];
                    let vkq = v[k * d + q];
                    v[k * d + p] = c * vkp - s * vkq;
                    v[k * d + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut order: Vec<usize> = (0..d).collect();
    let evals: Vec<f64> = (0..d).map(|i| m[i * d + i]).collect();
    order.sort_by(|&a, &b| evals[b].partial_cmp(&evals[a]).unwrap());
    let sorted_vals: Vec<f64> = order.iter().map(|&i| evals[i]).collect();
    let mut rows = vec![0f64; d * d];
    for (r, &i) in order.iter().enumerate() {
        for k in 0..d {
            rows[r * d + k] = v[k * d + i]; // column i of V → row r
        }
    }
    (sorted_vals, rows)
}

pub struct Pca {
    pub dim: usize,
    pub components: usize,
    pub mean: Vec<f64>,
    /// [components, dim] projection rows.
    pub basis: Vec<f64>,
}

impl Pca {
    /// Fit on row-major samples `x` ([n, dim]).
    pub fn fit(x: &[f32], dim: usize, components: usize) -> Pca {
        let n = x.len() / dim;
        assert!(n > 1, "need at least 2 samples");
        let components = components.min(dim);
        let mut mean = vec![0f64; dim];
        for row in 0..n {
            for j in 0..dim {
                mean[j] += x[row * dim + j] as f64;
            }
        }
        for m in &mut mean {
            *m /= n as f64;
        }
        let mut cov = vec![0f64; dim * dim];
        for row in 0..n {
            for i in 0..dim {
                let di = x[row * dim + i] as f64 - mean[i];
                for j in i..dim {
                    cov[i * dim + j] += di * (x[row * dim + j] as f64 - mean[j]);
                }
            }
        }
        for i in 0..dim {
            for j in 0..i {
                cov[i * dim + j] = cov[j * dim + i];
            }
        }
        for c in &mut cov {
            *c /= (n - 1) as f64;
        }
        let (_vals, vecs) = jacobi_eigen(&cov, dim, 30);
        Pca { dim, components, mean, basis: vecs[..components * dim].to_vec() }
    }

    /// Project samples into the component space, zero-padded to `out_dim`.
    pub fn encode(&self, x: &[f32], out_dim: usize) -> Vec<f32> {
        let n = x.len() / self.dim;
        let mut out = vec![0f32; n * out_dim];
        for row in 0..n {
            for c in 0..self.components.min(out_dim) {
                let mut acc = 0f64;
                for j in 0..self.dim {
                    acc += (x[row * self.dim + j] as f64 - self.mean[j])
                        * self.basis[c * self.dim + j];
                }
                out[row * out_dim + c] = acc as f32;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn jacobi_known_2x2() {
        // [[2, 1], [1, 2]] has eigenvalues 3 and 1.
        let (vals, vecs) = jacobi_eigen(&[2.0, 1.0, 1.0, 2.0], 2, 20);
        assert!((vals[0] - 3.0).abs() < 1e-10);
        assert!((vals[1] - 1.0).abs() < 1e-10);
        // Eigenvector for 3 is (1,1)/√2 up to sign.
        let r = &vecs[0..2];
        assert!((r[0].abs() - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-8);
        assert!((r[0] - r[1]).abs() < 1e-8);
    }

    #[test]
    fn pca_finds_dominant_direction() {
        // Points along (3, 1) with small noise.
        let mut rng = Rng::new(1);
        let mut x = Vec::new();
        for _ in 0..500 {
            let t = rng.next_gaussian();
            x.push((3.0 * t + 0.01 * rng.next_gaussian()) as f32);
            x.push((t + 0.01 * rng.next_gaussian()) as f32);
        }
        let pca = Pca::fit(&x, 2, 1);
        let dir = (pca.basis[0], pca.basis[1]);
        let norm = (dir.0 * dir.0 + dir.1 * dir.1).sqrt();
        let cos = (3.0 * dir.0 + dir.1) / (10f64.sqrt() * norm);
        assert!(cos.abs() > 0.999, "cos={cos}");
    }

    #[test]
    fn encode_shape_and_padding() {
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..64 * 16).map(|_| rng.next_f32()).collect();
        let pca = Pca::fit(&x, 16, 8);
        let z = pca.encode(&x[..16], 64);
        assert_eq!(z.len(), 64);
        assert!(z[8..].iter().all(|&v| v == 0.0), "padding must be zero");
    }

    #[test]
    fn reconstruction_error_decreases_with_components() {
        let mut rng = Rng::new(3);
        // Low-rank-ish data: 3 latent factors in 16 dims.
        let mix: Vec<f64> = (0..3 * 16).map(|_| rng.next_gaussian()).collect();
        let mut x = Vec::new();
        for _ in 0..300 {
            let f = [rng.next_gaussian(), rng.next_gaussian(), rng.next_gaussian()];
            for j in 0..16 {
                let v: f64 = (0..3).map(|k| f[k] * mix[k * 16 + j]).sum();
                x.push(v as f32 + 0.01 * rng.next_gaussian() as f32);
            }
        }
        let err = |comps: usize| -> f64 {
            let pca = Pca::fit(&x, 16, comps);
            // Project then measure captured variance via encoded norms.
            let z = pca.encode(&x, comps);
            let total: f64 = x.iter().map(|&v| (v as f64) * (v as f64)).sum();
            let captured: f64 = z.iter().map(|&v| (v as f64) * (v as f64)).sum();
            1.0 - captured / total
        };
        assert!(err(3) < err(1), "more components capture more variance");
        assert!(err(3) < 0.2, "3 components should capture a rank-3 signal");
    }
}
