//! Feature encodings of program configurations for the learned cost
//! models. Three encodings coexist:
//!
//! * **COGNATE** — a `MAPPED_DIM`-d homogeneous vector (the configuration
//!   mapper input) **plus** a `HET_DIM`-d heterogeneous vector (the
//!   latent-encoder / autoencoder input). Dim 53 matches the paper's
//!   configuration-embedding input (Table 6).
//! * **WACO+FM** — feature *mapping*: the homogeneous vector alone.
//! * **WACO+FA** — feature *augmentation* (Daumé III): the concatenation
//!   of all three platforms' raw blocks, with non-applicable blocks
//!   zeroed — deliberately sparse, which is the failure mode Figure 2/4
//!   demonstrates.

use super::mapping::{phi_spade, pi_cpu, pi_gpu, MappedConfig, NUM_SLOTS};
use super::space::{Config, PlatformId};

/// Homogeneous (mapped) vector width: 4 numeric + 7×7 order one-hot.
pub const MAPPED_DIM: usize = 4 + NUM_SLOTS * NUM_SLOTS; // 53

/// Heterogeneous vector width (padded union of platform-specific knobs).
/// Layout: [platform one-hot ×3 | cpu format one-hot ×4 |
///          spade bypass, spade reorder | gpu binding one-hot ×4 |
///          gpu log2(unroll)/2, gpu vectorize | pad] = 16.
pub const HET_DIM: usize = 16;

/// Feature-augmentation width: shared numeric(3) + cpu block(12) +
/// spade block(6) + gpu block(9) = 30.
pub const FA_DIM: usize = 30;

fn log_norm(x: usize) -> f32 {
    // log2 of strip sizes normalised to ≈[0,1] over the spaces we use.
    ((x.max(1) as f32).log2() / 17.0).min(1.5)
}

/// Encode a mapped config into the `MAPPED_DIM` homogeneous vector.
pub fn encode_mapped(m: &MappedConfig) -> Vec<f32> {
    let mut v = vec![0f32; MAPPED_DIM];
    v[0] = log_norm(m.i);
    v[1] = log_norm(m.j);
    v[2] = log_norm(m.k);
    v[3] = m.real_loops as f32 / NUM_SLOTS as f32;
    for (pos, slot) in m.order.iter().enumerate() {
        v[4 + pos * NUM_SLOTS + slot.index()] = 1.0;
    }
    v
}

/// Map + encode in one step for any platform config.
pub fn mapped_vector(cfg: &Config, matrix_cols: usize) -> Vec<f32> {
    let m = match cfg {
        Config::Cpu(c) => pi_cpu(c),
        Config::Spade(c) => phi_spade(c, matrix_cols),
        Config::Gpu(c) => pi_gpu(c),
    };
    encode_mapped(&m)
}

/// Encode the heterogeneous component (latent-encoder input).
pub fn het_vector(cfg: &Config) -> Vec<f32> {
    let mut v = vec![0f32; HET_DIM];
    match cfg {
        Config::Cpu(c) => {
            v[0] = 1.0;
            v[3 + c.format.index()] = 1.0;
        }
        Config::Spade(c) => {
            v[1] = 1.0;
            v[7] = c.bypass as u8 as f32;
            v[8] = c.reorder as u8 as f32;
        }
        Config::Gpu(c) => {
            v[2] = 1.0;
            v[9 + c.binding.index()] = 1.0;
            v[13] = (c.unroll as f32).log2() / 2.0;
            v[14] = c.vectorize as u8 as f32;
        }
    }
    v
}

/// Feature augmentation (WACO+FA): raw per-platform blocks concatenated;
/// blocks for other platforms are zero.
pub fn fa_vector(cfg: &Config, matrix_cols: usize) -> Vec<f32> {
    let mut v = vec![0f32; FA_DIM];
    match cfg {
        Config::Cpu(c) => {
            v[0] = log_norm(c.i_split);
            v[1] = log_norm(c.j_split);
            v[2] = log_norm(c.k_split);
            // cpu block: order one-hot(8) + format one-hot(4) at [3..15)
            v[3 + c.order.index()] = 1.0;
            v[11 + c.format.index()] = 1.0;
        }
        Config::Spade(c) => {
            v[0] = log_norm(c.resolved_col_panel(matrix_cols));
            v[1] = log_norm(c.row_panels);
            v[2] = log_norm(c.split);
            // spade block at [15..21): rowp log, colp log, split log,
            // barrier, bypass, reorder
            v[15] = log_norm(c.row_panels);
            v[16] = log_norm(c.resolved_col_panel(matrix_cols));
            v[17] = log_norm(c.split);
            v[18] = c.barrier as u8 as f32;
            v[19] = c.bypass as u8 as f32;
            v[20] = c.reorder as u8 as f32;
        }
        Config::Gpu(c) => {
            v[0] = log_norm(c.i_split);
            v[1] = 0.0;
            v[2] = log_norm(c.k1 * c.k2);
            // gpu block at [21..30): binding(4), unroll(3), vec, k2 log
            v[21 + c.binding.index()] = 1.0;
            let u = match c.unroll {
                1 => 0,
                2 => 1,
                _ => 2,
            };
            v[25 + u] = 1.0;
            v[28] = c.vectorize as u8 as f32;
            v[29] = log_norm(c.k2);
        }
    }
    v
}

/// Feature mapping (WACO+FM): the homogeneous vector only.
pub fn fm_vector(cfg: &Config, matrix_cols: usize) -> Vec<f32> {
    mapped_vector(cfg, matrix_cols)
}

/// The platform a `Config` belongs to.
pub fn platform_of(cfg: &Config) -> PlatformId {
    match cfg {
        Config::Cpu(_) => PlatformId::Cpu,
        Config::Spade(_) => PlatformId::Spade,
        Config::Gpu(_) => PlatformId::Gpu,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::space::{cpu_space, gpu_space, spade_space};

    #[test]
    fn dims_are_exact() {
        assert_eq!(MAPPED_DIM, 53); // paper Table 6: config embedding in=53
        let c = Config::Spade(spade_space()[7]);
        assert_eq!(mapped_vector(&c, 4096).len(), MAPPED_DIM);
        assert_eq!(het_vector(&c).len(), HET_DIM);
        assert_eq!(fa_vector(&c, 4096).len(), FA_DIM);
    }

    #[test]
    fn mapped_one_hot_rows_sum_to_one() {
        for cfg in [
            Config::Cpu(cpu_space()[33]),
            Config::Spade(spade_space()[99]),
            Config::Gpu(gpu_space()[120]),
        ] {
            let v = mapped_vector(&cfg, 2048);
            for pos in 0..NUM_SLOTS {
                let s: f32 = v[4 + pos * NUM_SLOTS..4 + (pos + 1) * NUM_SLOTS].iter().sum();
                assert!((s - 1.0).abs() < 1e-6, "pos {pos} sum {s}");
            }
        }
    }

    #[test]
    fn het_platform_one_hot() {
        let c = het_vector(&Config::Cpu(cpu_space()[0]));
        let s = het_vector(&Config::Spade(spade_space()[0]));
        let g = het_vector(&Config::Gpu(gpu_space()[0]));
        assert_eq!((c[0], c[1], c[2]), (1.0, 0.0, 0.0));
        assert_eq!((s[0], s[1], s[2]), (0.0, 1.0, 0.0));
        assert_eq!((g[0], g[1], g[2]), (0.0, 0.0, 1.0));
    }

    #[test]
    fn fa_blocks_are_disjointly_sparse() {
        // CPU config leaves spade+gpu blocks zero and vice versa —
        // exactly the sparsity pathology §3.3 describes.
        let c = fa_vector(&Config::Cpu(cpu_space()[5]), 1024);
        assert!(c[15..30].iter().all(|&x| x == 0.0));
        let s = fa_vector(&Config::Spade(spade_space()[5]), 1024);
        assert!(s[3..15].iter().all(|&x| x == 0.0));
        assert!(s[21..30].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn distinct_configs_distinct_vectors() {
        let space = spade_space();
        let mut seen = std::collections::HashSet::new();
        for c in space {
            let m = mapped_vector(&Config::Spade(*c), 4096);
            let h = het_vector(&Config::Spade(*c));
            let key: Vec<u32> = m.iter().chain(h.iter()).map(|f| f.to_bits()).collect();
            assert!(seen.insert(key), "collision for {c:?}");
        }
        assert_eq!(seen.len(), 256);
    }

    #[test]
    fn het_ignores_homogeneous_knobs() {
        let mut a = spade_space()[0];
        let mut b = a;
        a.barrier = false;
        b.barrier = true; // homogeneous (mapped via φ)
        assert_eq!(het_vector(&Config::Spade(a)), het_vector(&Config::Spade(b)));
        b.bypass = !a.bypass; // heterogeneous
        assert_ne!(het_vector(&Config::Spade(a)), het_vector(&Config::Spade(b)));
    }
}
