//! Program-configuration spaces for the three hardware platforms
//! (Table 1 of the paper).
//!
//! * **CPU (TACO)** — loop strip-mining (I, J, K), loop reordering
//!   (order over {i1,i2,j1,j2,k1,k2}), format reordering. 1,024 configs.
//! * **SPADE** — tiling (row panels × col panels × split factor),
//!   barrier, cache bypassing, matrix reordering. Exactly the paper's
//!   256-point space: {4,32,256,2048} × {1024,16384,65536,NUM_COLS} ×
//!   {32,256} × 2 × 2 × 2.
//! * **GPU (SparseTIR)** — strip-mining, loop binding, loop unrolling,
//!   vectorization. 288 configs ("approximately 300", §4.1).

use crate::sparse::reorder::Reorder;

// ---------------------------------------------------------------------------
// CPU (TACO)
// ---------------------------------------------------------------------------

/// Named loop orders over the strip-mined nest {i1,i2,j1,j2,k1,k2}.
/// `i` = rows of A, `j` = reduction (columns of A), `k` = dense columns.
/// Slot values match `mapping::Slot`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CpuOrder {
    /// i1 j1 k1 i2 j2 k2 — canonical row-major
    RowMajor,
    /// k1 i1 j1 i2 j2 k2 — dense-column strips hoisted outermost
    KOuter,
    /// j1 i1 k1 i2 j2 k2 — reduction panels outermost (B panel resident)
    JOuter,
    /// i1 k1 j1 i2 k2 j2 — inner reduction last (register-tile D)
    InnerJ,
    /// j1 k1 i1 j2 i2 k2 — B-stationary
    BStationary,
    /// k1 j1 i1 i2 j2 k2 — k then reduction outer
    KJOuter,
    /// i1 j1 i2 j2 k1 k2 — k innermost entirely (streaming D)
    KInner,
    /// i1 i2 j1 j2 k1 k2 — fully row-blocked then flat
    Flat,
}

pub const ALL_CPU_ORDERS: [CpuOrder; 8] = [
    CpuOrder::RowMajor,
    CpuOrder::KOuter,
    CpuOrder::JOuter,
    CpuOrder::InnerJ,
    CpuOrder::BStationary,
    CpuOrder::KJOuter,
    CpuOrder::KInner,
    CpuOrder::Flat,
];

impl CpuOrder {
    pub fn index(&self) -> usize {
        ALL_CPU_ORDERS.iter().position(|o| o == self).unwrap()
    }
}

pub const CPU_I_SPLITS: [usize; 4] = [16, 64, 256, 1024];
pub const CPU_J_SPLITS: [usize; 4] = [16, 64, 256, 1024];
pub const CPU_K_SPLITS: [usize; 2] = [8, 32];

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CpuConfig {
    pub i_split: usize,
    pub j_split: usize,
    pub k_split: usize,
    pub order: CpuOrder,
    pub format: Reorder,
}

// ---------------------------------------------------------------------------
// SPADE
// ---------------------------------------------------------------------------

pub const SPADE_ROW_PANELS: [usize; 4] = [4, 32, 256, 2048];
/// `0` encodes NUM_MATRIX_COLS (resolved against the input matrix).
pub const SPADE_COL_PANELS: [usize; 4] = [1024, 16384, 65536, 0];
pub const SPADE_SPLITS: [usize; 2] = [32, 256];

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SpadeConfig {
    /// Rows per row panel.
    pub row_panels: usize,
    /// Columns (of A) per column panel; `0` = whole matrix width.
    pub col_panels: usize,
    /// Dense-dimension split factor.
    pub split: usize,
    pub barrier: bool,
    pub bypass: bool,
    pub reorder: bool,
}

impl SpadeConfig {
    /// Resolve `col_panels == 0` (NUM_MATRIX_COLS) against a matrix width.
    pub fn resolved_col_panel(&self, cols: usize) -> usize {
        if self.col_panels == 0 {
            cols.max(1)
        } else {
            self.col_panels
        }
    }
}

// ---------------------------------------------------------------------------
// GPU (SparseTIR)
// ---------------------------------------------------------------------------

/// Loop-binding strategies (which loop is bound to which execution unit).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GpuBinding {
    /// One row per thread — fine-grained, divergence-prone on skew.
    RowPerThread,
    /// One row per warp — good for long rows, wasteful on short ones.
    RowPerWarp,
    /// Row block per threadblock with per-thread k partition.
    RowPerBlock,
    /// Nnz-balanced split with atomic combine.
    NnzBalanced,
}

pub const ALL_GPU_BINDINGS: [GpuBinding; 4] = [
    GpuBinding::RowPerThread,
    GpuBinding::RowPerWarp,
    GpuBinding::RowPerBlock,
    GpuBinding::NnzBalanced,
];

impl GpuBinding {
    pub fn index(&self) -> usize {
        ALL_GPU_BINDINGS.iter().position(|b| b == self).unwrap()
    }
}

pub const GPU_I_SPLITS: [usize; 3] = [16, 64, 256];
pub const GPU_K1_SPLITS: [usize; 2] = [8, 32];
pub const GPU_K2_SPLITS: [usize; 2] = [2, 8];
pub const GPU_UNROLLS: [usize; 3] = [1, 2, 4];

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GpuConfig {
    pub i_split: usize,
    pub k1: usize,
    pub k2: usize,
    pub binding: GpuBinding,
    pub unroll: usize,
    pub vectorize: bool,
}

// ---------------------------------------------------------------------------
// Unified enumeration
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Config {
    Cpu(CpuConfig),
    Spade(SpadeConfig),
    Gpu(GpuConfig),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlatformId {
    Cpu,
    Spade,
    Gpu,
}

impl PlatformId {
    pub fn name(&self) -> &'static str {
        match self {
            PlatformId::Cpu => "cpu",
            PlatformId::Spade => "spade",
            PlatformId::Gpu => "gpu",
        }
    }
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "cpu" => Some(PlatformId::Cpu),
            "spade" => Some(PlatformId::Spade),
            "gpu" => Some(PlatformId::Gpu),
            _ => None,
        }
    }
    pub fn index(&self) -> usize {
        match self {
            PlatformId::Cpu => 0,
            PlatformId::Spade => 1,
            PlatformId::Gpu => 2,
        }
    }
}

/// Enumerate the full CPU space (1,024 configs), index-stable.
pub fn cpu_space() -> Vec<CpuConfig> {
    let mut v = Vec::with_capacity(1024);
    for &i_split in &CPU_I_SPLITS {
        for &j_split in &CPU_J_SPLITS {
            for &k_split in &CPU_K_SPLITS {
                for &order in &ALL_CPU_ORDERS {
                    for &format in &crate::sparse::reorder::ALL_REORDERS {
                        v.push(CpuConfig { i_split, j_split, k_split, order, format });
                    }
                }
            }
        }
    }
    v
}

/// Enumerate the SPADE space (exactly 256 configs), index-stable.
pub fn spade_space() -> Vec<SpadeConfig> {
    let mut v = Vec::with_capacity(256);
    for &row_panels in &SPADE_ROW_PANELS {
        for &col_panels in &SPADE_COL_PANELS {
            for &split in &SPADE_SPLITS {
                for barrier in [false, true] {
                    for bypass in [false, true] {
                        for reorder in [false, true] {
                            v.push(SpadeConfig {
                                row_panels,
                                col_panels,
                                split,
                                barrier,
                                bypass,
                                reorder,
                            });
                        }
                    }
                }
            }
        }
    }
    v
}

/// Enumerate the GPU space (288 configs), index-stable.
pub fn gpu_space() -> Vec<GpuConfig> {
    let mut v = Vec::with_capacity(288);
    for &i_split in &GPU_I_SPLITS {
        for &k1 in &GPU_K1_SPLITS {
            for &k2 in &GPU_K2_SPLITS {
                for &binding in &ALL_GPU_BINDINGS {
                    for &unroll in &GPU_UNROLLS {
                        for vectorize in [false, true] {
                            v.push(GpuConfig { i_split, k1, k2, binding, unroll, vectorize });
                        }
                    }
                }
            }
        }
    }
    v
}

/// Index of each platform's *default* configuration — the programming
/// system's out-of-the-box schedule, used as the speedup baseline.
pub fn default_config_index(p: PlatformId) -> usize {
    match p {
        PlatformId::Cpu => {
            let space = cpu_space();
            space
                .iter()
                .position(|c| {
                    c.i_split == 256
                        && c.j_split == 1024
                        && c.k_split == 32
                        && c.order == CpuOrder::RowMajor
                        && c.format == Reorder::None
                })
                .unwrap()
        }
        PlatformId::Spade => {
            let space = spade_space();
            space
                .iter()
                .position(|c| {
                    c.row_panels == 256
                        && c.col_panels == 0
                        && c.split == 32
                        && !c.barrier
                        && !c.bypass
                        && !c.reorder
                })
                .unwrap()
        }
        PlatformId::Gpu => {
            let space = gpu_space();
            space
                .iter()
                .position(|c| {
                    c.i_split == 64
                        && c.k1 == 32
                        && c.k2 == 2
                        && c.binding == GpuBinding::RowPerThread
                        && c.unroll == 1
                        && !c.vectorize
                })
                .unwrap()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spade_space_is_exactly_256() {
        let s = spade_space();
        assert_eq!(s.len(), 256);
        // All unique.
        let mut set = std::collections::HashSet::new();
        for c in &s {
            assert!(set.insert(*c));
        }
    }

    #[test]
    fn cpu_space_is_1024() {
        assert_eq!(cpu_space().len(), 1024);
    }

    #[test]
    fn gpu_space_is_about_300() {
        let n = gpu_space().len();
        assert_eq!(n, 288);
        assert!((250..=350).contains(&n), "paper says ~300");
    }

    #[test]
    fn default_indices_resolve() {
        for p in [PlatformId::Cpu, PlatformId::Spade, PlatformId::Gpu] {
            let idx = default_config_index(p);
            let n = match p {
                PlatformId::Cpu => cpu_space().len(),
                PlatformId::Spade => spade_space().len(),
                PlatformId::Gpu => gpu_space().len(),
            };
            assert!(idx < n);
        }
    }

    #[test]
    fn col_panel_resolution() {
        let c = SpadeConfig {
            row_panels: 4,
            col_panels: 0,
            split: 32,
            barrier: false,
            bypass: false,
            reorder: false,
        };
        assert_eq!(c.resolved_col_panel(777), 777);
        let c2 = SpadeConfig { col_panels: 1024, ..c };
        assert_eq!(c2.resolved_col_panel(777), 1024);
    }

    #[test]
    fn spaces_index_stable() {
        // Regression guard: dataset files store config indices; the
        // enumeration order must never change silently.
        let s = spade_space();
        assert_eq!(
            s[0],
            SpadeConfig {
                row_panels: 4,
                col_panels: 1024,
                split: 32,
                barrier: false,
                bypass: false,
                reorder: false
            }
        );
        assert_eq!(
            s[255],
            SpadeConfig {
                row_panels: 2048,
                col_panels: 0,
                split: 256,
                barrier: true,
                bypass: true,
                reorder: true
            }
        );
    }
}
