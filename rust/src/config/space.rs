//! Program-configuration spaces for the three hardware platforms
//! (Table 1 of the paper).
//!
//! * **CPU (TACO)** — loop strip-mining (I, J, K), loop reordering
//!   (order over {i1,i2,j1,j2,k1,k2}), format reordering. 1,024 configs.
//! * **SPADE** — tiling (row panels × col panels × split factor),
//!   barrier, cache bypassing, matrix reordering. Exactly the paper's
//!   256-point space: {4,32,256,2048} × {1024,16384,65536,NUM_COLS} ×
//!   {32,256} × 2 × 2 × 2.
//! * **GPU (SparseTIR)** — strip-mining, loop binding, loop unrolling,
//!   vectorization. 288 configs ("approximately 300", §4.1).
//!
//! Each space is a dense mixed-radix enumeration: a config index is the
//! knob digits read outermost-first (the same nesting order as the
//! `build_*_space` loops), so `index_of`/`config_at` convert between a
//! `Config` and its index with pure arithmetic — no table scans. The
//! enumerated `Vec`s themselves are built once per process behind
//! `OnceLock`s and handed out as `&'static` slices.

use crate::sparse::reorder::{Reorder, ALL_REORDERS};
use std::sync::OnceLock;

// ---------------------------------------------------------------------------
// CPU (TACO)
// ---------------------------------------------------------------------------

/// Named loop orders over the strip-mined nest {i1,i2,j1,j2,k1,k2}.
/// `i` = rows of A, `j` = reduction (columns of A), `k` = dense columns.
/// Slot values match `mapping::Slot`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CpuOrder {
    /// i1 j1 k1 i2 j2 k2 — canonical row-major
    RowMajor,
    /// k1 i1 j1 i2 j2 k2 — dense-column strips hoisted outermost
    KOuter,
    /// j1 i1 k1 i2 j2 k2 — reduction panels outermost (B panel resident)
    JOuter,
    /// i1 k1 j1 i2 k2 j2 — inner reduction last (register-tile D)
    InnerJ,
    /// j1 k1 i1 j2 i2 k2 — B-stationary
    BStationary,
    /// k1 j1 i1 i2 j2 k2 — k then reduction outer
    KJOuter,
    /// i1 j1 i2 j2 k1 k2 — k innermost entirely (streaming D)
    KInner,
    /// i1 i2 j1 j2 k1 k2 — fully row-blocked then flat
    Flat,
}

pub const ALL_CPU_ORDERS: [CpuOrder; 8] = [
    CpuOrder::RowMajor,
    CpuOrder::KOuter,
    CpuOrder::JOuter,
    CpuOrder::InnerJ,
    CpuOrder::BStationary,
    CpuOrder::KJOuter,
    CpuOrder::KInner,
    CpuOrder::Flat,
];

impl CpuOrder {
    /// Position in `ALL_CPU_ORDERS` (declaration order == array order).
    pub fn index(&self) -> usize {
        *self as usize
    }
}

pub const CPU_I_SPLITS: [usize; 4] = [16, 64, 256, 1024];
pub const CPU_J_SPLITS: [usize; 4] = [16, 64, 256, 1024];
pub const CPU_K_SPLITS: [usize; 2] = [8, 32];

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CpuConfig {
    pub i_split: usize,
    pub j_split: usize,
    pub k_split: usize,
    pub order: CpuOrder,
    pub format: Reorder,
}

// ---------------------------------------------------------------------------
// SPADE
// ---------------------------------------------------------------------------

pub const SPADE_ROW_PANELS: [usize; 4] = [4, 32, 256, 2048];
/// `0` encodes NUM_MATRIX_COLS (resolved against the input matrix).
pub const SPADE_COL_PANELS: [usize; 4] = [1024, 16384, 65536, 0];
pub const SPADE_SPLITS: [usize; 2] = [32, 256];

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SpadeConfig {
    /// Rows per row panel.
    pub row_panels: usize,
    /// Columns (of A) per column panel; `0` = whole matrix width.
    pub col_panels: usize,
    /// Dense-dimension split factor.
    pub split: usize,
    pub barrier: bool,
    pub bypass: bool,
    pub reorder: bool,
}

impl SpadeConfig {
    /// Resolve `col_panels == 0` (NUM_MATRIX_COLS) against a matrix width.
    pub fn resolved_col_panel(&self, cols: usize) -> usize {
        if self.col_panels == 0 {
            cols.max(1)
        } else {
            self.col_panels
        }
    }
}

// ---------------------------------------------------------------------------
// GPU (SparseTIR)
// ---------------------------------------------------------------------------

/// Loop-binding strategies (which loop is bound to which execution unit).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GpuBinding {
    /// One row per thread — fine-grained, divergence-prone on skew.
    RowPerThread,
    /// One row per warp — good for long rows, wasteful on short ones.
    RowPerWarp,
    /// Row block per threadblock with per-thread k partition.
    RowPerBlock,
    /// Nnz-balanced split with atomic combine.
    NnzBalanced,
}

pub const ALL_GPU_BINDINGS: [GpuBinding; 4] = [
    GpuBinding::RowPerThread,
    GpuBinding::RowPerWarp,
    GpuBinding::RowPerBlock,
    GpuBinding::NnzBalanced,
];

impl GpuBinding {
    /// Position in `ALL_GPU_BINDINGS` (declaration order == array order).
    pub fn index(&self) -> usize {
        *self as usize
    }
}

pub const GPU_I_SPLITS: [usize; 3] = [16, 64, 256];
pub const GPU_K1_SPLITS: [usize; 2] = [8, 32];
pub const GPU_K2_SPLITS: [usize; 2] = [2, 8];
pub const GPU_UNROLLS: [usize; 3] = [1, 2, 4];

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GpuConfig {
    pub i_split: usize,
    pub k1: usize,
    pub k2: usize,
    pub binding: GpuBinding,
    pub unroll: usize,
    pub vectorize: bool,
}

// ---------------------------------------------------------------------------
// Unified enumeration
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Config {
    Cpu(CpuConfig),
    Spade(SpadeConfig),
    Gpu(GpuConfig),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlatformId {
    Cpu,
    Spade,
    Gpu,
}

impl PlatformId {
    pub fn name(&self) -> &'static str {
        match self {
            PlatformId::Cpu => "cpu",
            PlatformId::Spade => "spade",
            PlatformId::Gpu => "gpu",
        }
    }
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "cpu" => Some(PlatformId::Cpu),
            "spade" => Some(PlatformId::Spade),
            "gpu" => Some(PlatformId::Gpu),
            _ => None,
        }
    }
    pub fn index(&self) -> usize {
        match self {
            PlatformId::Cpu => 0,
            PlatformId::Spade => 1,
            PlatformId::Gpu => 2,
        }
    }
}

// ---------------------------------------------------------------------------
// Mixed-radix index encoding
// ---------------------------------------------------------------------------

/// Knob radices, outermost (most-significant digit) first. The order
/// mirrors the `build_*_space` loop nests, so digit `d` of an index is
/// knob `d` of the enumeration.
pub const CPU_RADICES: [usize; 5] = [4, 4, 2, 8, 4]; // i, j, k, order, format
pub const SPADE_RADICES: [usize; 6] = [4, 4, 2, 2, 2, 2]; // row, col, split, bar, byp, reord
pub const GPU_RADICES: [usize; 6] = [3, 2, 2, 4, 3, 2]; // i, k1, k2, bind, unroll, vec

pub const CPU_SPACE_LEN: usize = 1024;
pub const SPADE_SPACE_LEN: usize = 256;
pub const GPU_SPACE_LEN: usize = 288;

/// Knob radices of a platform's space, outermost digit first.
pub fn radices(p: PlatformId) -> &'static [usize] {
    match p {
        PlatformId::Cpu => &CPU_RADICES,
        PlatformId::Spade => &SPADE_RADICES,
        PlatformId::Gpu => &GPU_RADICES,
    }
}

/// Total number of configs in a platform's space (no enumeration).
pub fn space_len(p: PlatformId) -> usize {
    match p {
        PlatformId::Cpu => CPU_SPACE_LEN,
        PlatformId::Spade => SPADE_SPACE_LEN,
        PlatformId::Gpu => GPU_SPACE_LEN,
    }
}

/// Place value (index stride) of knob `dim`: the product of all radices
/// inner to it. `O(#knobs)`, independent of the space size.
#[inline]
pub fn knob_stride(p: PlatformId, dim: usize) -> usize {
    radices(p)[dim + 1..].iter().product()
}

/// Digit `dim` of `idx` in the platform's mixed-radix encoding.
#[inline]
pub fn knob_digit(p: PlatformId, idx: usize, dim: usize) -> usize {
    (idx / knob_stride(p, dim)) % radices(p)[dim]
}

/// Position of a knob *value* in its (tiny, constant-size) value array.
#[inline]
fn pos(arr: &[usize], v: usize) -> usize {
    let mut i = 0;
    while i < arr.len() {
        if arr[i] == v {
            return i;
        }
        i += 1;
    }
    panic!("knob value {v} not in the config space");
}

/// Index of a CPU config — pure mixed-radix arithmetic, no scan.
pub fn cpu_index_of(c: &CpuConfig) -> usize {
    let i = pos(&CPU_I_SPLITS, c.i_split);
    let j = pos(&CPU_J_SPLITS, c.j_split);
    let k = pos(&CPU_K_SPLITS, c.k_split);
    (((i * CPU_RADICES[1] + j) * CPU_RADICES[2] + k) * CPU_RADICES[3] + c.order.index())
        * CPU_RADICES[4]
        + c.format.index()
}

/// Index of a SPADE config — pure mixed-radix arithmetic, no scan.
pub fn spade_index_of(c: &SpadeConfig) -> usize {
    let r = pos(&SPADE_ROW_PANELS, c.row_panels);
    let cp = pos(&SPADE_COL_PANELS, c.col_panels);
    let s = pos(&SPADE_SPLITS, c.split);
    ((((r * SPADE_RADICES[1] + cp) * SPADE_RADICES[2] + s) * SPADE_RADICES[3]
        + c.barrier as usize)
        * SPADE_RADICES[4]
        + c.bypass as usize)
        * SPADE_RADICES[5]
        + c.reorder as usize
}

/// Index of a GPU config — pure mixed-radix arithmetic, no scan.
pub fn gpu_index_of(c: &GpuConfig) -> usize {
    let i = pos(&GPU_I_SPLITS, c.i_split);
    let k1 = pos(&GPU_K1_SPLITS, c.k1);
    let k2 = pos(&GPU_K2_SPLITS, c.k2);
    let u = pos(&GPU_UNROLLS, c.unroll);
    ((((i * GPU_RADICES[1] + k1) * GPU_RADICES[2] + k2) * GPU_RADICES[3]
        + c.binding.index())
        * GPU_RADICES[4]
        + u)
        * GPU_RADICES[5]
        + c.vectorize as usize
}

/// Index of any config in its platform's enumeration.
pub fn index_of(c: &Config) -> usize {
    match c {
        Config::Cpu(c) => cpu_index_of(c),
        Config::Spade(c) => spade_index_of(c),
        Config::Gpu(c) => gpu_index_of(c),
    }
}

/// Decode an index into a CPU config (inverse of `cpu_index_of`).
pub fn cpu_config_at(idx: usize) -> CpuConfig {
    debug_assert!(idx < CPU_SPACE_LEN);
    let f = idx % CPU_RADICES[4];
    let idx = idx / CPU_RADICES[4];
    let o = idx % CPU_RADICES[3];
    let idx = idx / CPU_RADICES[3];
    let k = idx % CPU_RADICES[2];
    let idx = idx / CPU_RADICES[2];
    let j = idx % CPU_RADICES[1];
    let i = idx / CPU_RADICES[1];
    CpuConfig {
        i_split: CPU_I_SPLITS[i],
        j_split: CPU_J_SPLITS[j],
        k_split: CPU_K_SPLITS[k],
        order: ALL_CPU_ORDERS[o],
        format: ALL_REORDERS[f],
    }
}

/// Decode an index into a SPADE config (inverse of `spade_index_of`).
pub fn spade_config_at(idx: usize) -> SpadeConfig {
    debug_assert!(idx < SPADE_SPACE_LEN);
    let reorder = idx % 2 == 1;
    let idx = idx / 2;
    let bypass = idx % 2 == 1;
    let idx = idx / 2;
    let barrier = idx % 2 == 1;
    let idx = idx / 2;
    let s = idx % SPADE_RADICES[2];
    let idx = idx / SPADE_RADICES[2];
    let cp = idx % SPADE_RADICES[1];
    let r = idx / SPADE_RADICES[1];
    SpadeConfig {
        row_panels: SPADE_ROW_PANELS[r],
        col_panels: SPADE_COL_PANELS[cp],
        split: SPADE_SPLITS[s],
        barrier,
        bypass,
        reorder,
    }
}

/// Decode an index into a GPU config (inverse of `gpu_index_of`).
pub fn gpu_config_at(idx: usize) -> GpuConfig {
    debug_assert!(idx < GPU_SPACE_LEN);
    let vectorize = idx % 2 == 1;
    let idx = idx / 2;
    let u = idx % GPU_RADICES[4];
    let idx = idx / GPU_RADICES[4];
    let b = idx % GPU_RADICES[3];
    let idx = idx / GPU_RADICES[3];
    let k2 = idx % GPU_RADICES[2];
    let idx = idx / GPU_RADICES[2];
    let k1 = idx % GPU_RADICES[1];
    let i = idx / GPU_RADICES[1];
    GpuConfig {
        i_split: GPU_I_SPLITS[i],
        k1: GPU_K1_SPLITS[k1],
        k2: GPU_K2_SPLITS[k2],
        binding: ALL_GPU_BINDINGS[b],
        unroll: GPU_UNROLLS[u],
        vectorize,
    }
}

/// Decode an index on any platform.
pub fn config_at(p: PlatformId, idx: usize) -> Config {
    match p {
        PlatformId::Cpu => Config::Cpu(cpu_config_at(idx)),
        PlatformId::Spade => Config::Spade(spade_config_at(idx)),
        PlatformId::Gpu => Config::Gpu(gpu_config_at(idx)),
    }
}

// ---------------------------------------------------------------------------
// Memoized enumerations
// ---------------------------------------------------------------------------

fn build_cpu_space() -> Vec<CpuConfig> {
    let mut v = Vec::with_capacity(CPU_SPACE_LEN);
    for &i_split in &CPU_I_SPLITS {
        for &j_split in &CPU_J_SPLITS {
            for &k_split in &CPU_K_SPLITS {
                for &order in &ALL_CPU_ORDERS {
                    for &format in &ALL_REORDERS {
                        v.push(CpuConfig { i_split, j_split, k_split, order, format });
                    }
                }
            }
        }
    }
    v
}

fn build_spade_space() -> Vec<SpadeConfig> {
    let mut v = Vec::with_capacity(SPADE_SPACE_LEN);
    for &row_panels in &SPADE_ROW_PANELS {
        for &col_panels in &SPADE_COL_PANELS {
            for &split in &SPADE_SPLITS {
                for barrier in [false, true] {
                    for bypass in [false, true] {
                        for reorder in [false, true] {
                            v.push(SpadeConfig {
                                row_panels,
                                col_panels,
                                split,
                                barrier,
                                bypass,
                                reorder,
                            });
                        }
                    }
                }
            }
        }
    }
    v
}

fn build_gpu_space() -> Vec<GpuConfig> {
    let mut v = Vec::with_capacity(GPU_SPACE_LEN);
    for &i_split in &GPU_I_SPLITS {
        for &k1 in &GPU_K1_SPLITS {
            for &k2 in &GPU_K2_SPLITS {
                for &binding in &ALL_GPU_BINDINGS {
                    for &unroll in &GPU_UNROLLS {
                        for vectorize in [false, true] {
                            v.push(GpuConfig { i_split, k1, k2, binding, unroll, vectorize });
                        }
                    }
                }
            }
        }
    }
    v
}

/// The full CPU space (1,024 configs), index-stable, built once per
/// process.
pub fn cpu_space() -> &'static [CpuConfig] {
    static SPACE: OnceLock<Vec<CpuConfig>> = OnceLock::new();
    SPACE.get_or_init(build_cpu_space).as_slice()
}

/// The SPADE space (exactly 256 configs), index-stable, built once per
/// process.
pub fn spade_space() -> &'static [SpadeConfig] {
    static SPACE: OnceLock<Vec<SpadeConfig>> = OnceLock::new();
    SPACE.get_or_init(build_spade_space).as_slice()
}

/// The GPU space (288 configs), index-stable, built once per process.
pub fn gpu_space() -> &'static [GpuConfig] {
    static SPACE: OnceLock<Vec<GpuConfig>> = OnceLock::new();
    SPACE.get_or_init(build_gpu_space).as_slice()
}

/// Index of each platform's *default* configuration — the programming
/// system's out-of-the-box schedule, used as the speedup baseline.
/// Computed arithmetically; no space scan.
pub fn default_config_index(p: PlatformId) -> usize {
    match p {
        PlatformId::Cpu => cpu_index_of(&CpuConfig {
            i_split: 256,
            j_split: 1024,
            k_split: 32,
            order: CpuOrder::RowMajor,
            format: Reorder::None,
        }),
        PlatformId::Spade => spade_index_of(&SpadeConfig {
            row_panels: 256,
            col_panels: 0,
            split: 32,
            barrier: false,
            bypass: false,
            reorder: false,
        }),
        PlatformId::Gpu => gpu_index_of(&GpuConfig {
            i_split: 64,
            k1: 32,
            k2: 2,
            binding: GpuBinding::RowPerThread,
            unroll: 1,
            vectorize: false,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spade_space_is_exactly_256() {
        let s = spade_space();
        assert_eq!(s.len(), 256);
        // All unique.
        let mut set = std::collections::HashSet::new();
        for c in s {
            assert!(set.insert(*c));
        }
    }

    #[test]
    fn cpu_space_is_1024() {
        assert_eq!(cpu_space().len(), 1024);
    }

    #[test]
    fn gpu_space_is_about_300() {
        let n = gpu_space().len();
        assert_eq!(n, 288);
        assert!((250..=350).contains(&n), "paper says ~300");
    }

    #[test]
    fn default_indices_resolve() {
        for p in [PlatformId::Cpu, PlatformId::Spade, PlatformId::Gpu] {
            let idx = default_config_index(p);
            let n = match p {
                PlatformId::Cpu => cpu_space().len(),
                PlatformId::Spade => spade_space().len(),
                PlatformId::Gpu => gpu_space().len(),
            };
            assert!(idx < n);
        }
    }

    #[test]
    fn col_panel_resolution() {
        let c = SpadeConfig {
            row_panels: 4,
            col_panels: 0,
            split: 32,
            barrier: false,
            bypass: false,
            reorder: false,
        };
        assert_eq!(c.resolved_col_panel(777), 777);
        let c2 = SpadeConfig { col_panels: 1024, ..c };
        assert_eq!(c2.resolved_col_panel(777), 1024);
    }

    #[test]
    fn spaces_index_stable() {
        // Regression guard: dataset files store config indices; the
        // enumeration order must never change silently.
        let s = spade_space();
        assert_eq!(
            s[0],
            SpadeConfig {
                row_panels: 4,
                col_panels: 1024,
                split: 32,
                barrier: false,
                bypass: false,
                reorder: false
            }
        );
        assert_eq!(
            s[255],
            SpadeConfig {
                row_panels: 2048,
                col_panels: 0,
                split: 256,
                barrier: true,
                bypass: true,
                reorder: true
            }
        );
    }

    #[test]
    fn spaces_are_memoized() {
        // OnceLock: repeated calls return the same allocation.
        assert!(std::ptr::eq(cpu_space(), cpu_space()));
        assert!(std::ptr::eq(spade_space(), spade_space()));
        assert!(std::ptr::eq(gpu_space(), gpu_space()));
    }

    #[test]
    fn radices_consistent_with_lens() {
        for p in [PlatformId::Cpu, PlatformId::Spade, PlatformId::Gpu] {
            let prod: usize = radices(p).iter().product();
            assert_eq!(prod, space_len(p));
            let enumerated = match p {
                PlatformId::Cpu => cpu_space().len(),
                PlatformId::Spade => spade_space().len(),
                PlatformId::Gpu => gpu_space().len(),
            };
            assert_eq!(enumerated, space_len(p));
        }
    }

    #[test]
    fn index_roundtrip_full_space() {
        // index_of(config_at(i)) == i and config_at matches the
        // enumerated space at every index, on every platform.
        for p in [PlatformId::Cpu, PlatformId::Spade, PlatformId::Gpu] {
            for i in 0..space_len(p) {
                let c = config_at(p, i);
                assert_eq!(index_of(&c), i, "platform {} idx {i}", p.name());
            }
        }
        for (i, c) in cpu_space().iter().enumerate() {
            assert_eq!(cpu_config_at(i), *c);
            assert_eq!(cpu_index_of(c), i);
        }
        for (i, c) in spade_space().iter().enumerate() {
            assert_eq!(spade_config_at(i), *c);
            assert_eq!(spade_index_of(c), i);
        }
        for (i, c) in gpu_space().iter().enumerate() {
            assert_eq!(gpu_config_at(i), *c);
            assert_eq!(gpu_index_of(c), i);
        }
    }

    #[test]
    fn default_index_matches_enumeration() {
        // The arithmetic default must agree with a linear scan of the
        // enumerated space (the seed implementation's behaviour).
        let cd = default_config_index(PlatformId::Cpu);
        assert_eq!(
            cpu_space()[cd],
            CpuConfig {
                i_split: 256,
                j_split: 1024,
                k_split: 32,
                order: CpuOrder::RowMajor,
                format: Reorder::None,
            }
        );
        let sd = default_config_index(PlatformId::Spade);
        assert_eq!(
            spade_space()[sd],
            SpadeConfig {
                row_panels: 256,
                col_panels: 0,
                split: 32,
                barrier: false,
                bypass: false,
                reorder: false,
            }
        );
        let gd = default_config_index(PlatformId::Gpu);
        assert_eq!(
            gpu_space()[gd],
            GpuConfig {
                i_split: 64,
                k1: 32,
                k2: 2,
                binding: GpuBinding::RowPerThread,
                unroll: 1,
                vectorize: false,
            }
        );
    }

    #[test]
    fn enum_discriminants_match_arrays() {
        // `index()` relies on declaration order == array order.
        for (i, o) in ALL_CPU_ORDERS.iter().enumerate() {
            assert_eq!(o.index(), i);
        }
        for (i, b) in ALL_GPU_BINDINGS.iter().enumerate() {
            assert_eq!(b.index(), i);
        }
    }

    #[test]
    fn knob_digit_and_stride() {
        // Innermost knob has stride 1; outermost stride == len / radix.
        for p in [PlatformId::Cpu, PlatformId::Spade, PlatformId::Gpu] {
            let r = radices(p);
            assert_eq!(knob_stride(p, r.len() - 1), 1);
            assert_eq!(knob_stride(p, 0), space_len(p) / r[0]);
            // Reassembling digits reproduces the index.
            let idx = space_len(p) - 1;
            let rebuilt: usize =
                (0..r.len()).map(|d| knob_digit(p, idx, d) * knob_stride(p, d)).sum();
            assert_eq!(rebuilt, idx);
        }
    }
}
