//! Program-configuration spaces (Table 1), the §3.2 homogeneous mapping
//! functions φ/π, and the feature encodings (COGNATE mapped+het,
//! WACO+FA, WACO+FM) consumed by the learned cost models.

pub mod encode;
pub mod mapping;
pub mod space;

pub use encode::{fa_vector, fm_vector, het_vector, mapped_vector, FA_DIM, HET_DIM, MAPPED_DIM};
pub use mapping::{phi_spade, pi_cpu, pi_gpu, MappedConfig, Slot, NUM_SLOTS};
pub use space::{
    config_at, cpu_config_at, cpu_index_of, cpu_space, default_config_index, gpu_config_at,
    gpu_index_of, gpu_space, index_of, knob_digit, knob_stride, radices, space_len,
    spade_config_at, spade_index_of, spade_space, Config, CpuConfig, CpuOrder, GpuBinding,
    GpuConfig, PlatformId, SpadeConfig, ALL_CPU_ORDERS, ALL_GPU_BINDINGS, CPU_I_SPLITS,
    CPU_J_SPLITS, CPU_K_SPLITS, CPU_RADICES, CPU_SPACE_LEN, GPU_I_SPLITS, GPU_K1_SPLITS,
    GPU_K2_SPLITS, GPU_RADICES, GPU_SPACE_LEN, GPU_UNROLLS, SPADE_COL_PANELS, SPADE_RADICES,
    SPADE_ROW_PANELS, SPADE_SPACE_LEN, SPADE_SPLITS,
};
