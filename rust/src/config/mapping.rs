//! §3.2 — Exploiting homogeneity: approximate mapping of comparable code
//! optimizations into a unified strip-mining representation.
//!
//! Every platform's homogeneous component maps to `(I, J, K, ω)`:
//!   * `I` — row-dimension strip size,
//!   * `J` — reduction-dimension (columns of A) strip size,
//!   * `K` — dense-dimension strip size,
//!   * `ω` — execution order of the seven unified loop slots
//!     {i1,i2,j1,j2,k1,k2,k3} (outermost first).
//!
//! The paper's mapping functions are implemented verbatim:
//!   * φ : SPADE {p_col, p_row, s_split, b} → {I, J, K, ω}, where the
//!     barrier bit selects between the two §3.2 orders
//!     (b=1 ⇒ [k2, j2, i2, i1, j1, k1], b=0 ⇒ [k2, i2, j2, i1, j1, k1]);
//!   * π_a1 : CPU six-loop nests gain a unit k3 after k2;
//!   * π_a3 : GPU nests {i1,i2,j,k1,k2,k3} gain a unit j' after j.

use super::space::{CpuConfig, CpuOrder, GpuBinding, GpuConfig, SpadeConfig};

/// Unified loop slots. `J2`/`K3` are unit loops for platforms that do not
/// split that dimension (the π functions' appended loops).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Slot {
    I1,
    I2,
    J1,
    J2,
    K1,
    K2,
    K3,
}

pub const NUM_SLOTS: usize = 7;

impl Slot {
    pub fn index(&self) -> usize {
        match self {
            Slot::I1 => 0,
            Slot::I2 => 1,
            Slot::J1 => 2,
            Slot::J2 => 3,
            Slot::K1 => 4,
            Slot::K2 => 5,
            Slot::K3 => 6,
        }
    }
}

/// A configuration mapped into the unified homogeneous space.
#[derive(Clone, Debug, PartialEq)]
pub struct MappedConfig {
    pub i: usize,
    pub j: usize,
    pub k: usize,
    /// Execution order, outermost first; always all 7 slots.
    pub order: [Slot; NUM_SLOTS],
    /// How many of the slots are *real* (non-unit) loops on the platform.
    pub real_loops: usize,
}

/// φ — SPADE tiling + barrier → strip-mining + order (§3.2).
///
/// `I ≈ p_col` (column panel = reduction strip over A's columns... the
/// paper's I/J naming maps its (i,j,k) = (rows, reduction, dense) onto
/// SPADE (p_col, p_row, d_split) as I≈p_col, J≈p_row, K≈s_split —
/// we keep the paper's assignment exactly).
pub fn phi_spade(c: &SpadeConfig, matrix_cols: usize) -> MappedConfig {
    use Slot::*;
    let order = if c.barrier {
        // [k2, j2, i2, i1, j1, k1] + appended unit k3
        [K2, J2, I2, I1, J1, K1, K3]
    } else {
        // [k2, i2, j2, i1, j1, k1] + appended unit k3
        [K2, I2, J2, I1, J1, K1, K3]
    };
    MappedConfig {
        i: c.resolved_col_panel(matrix_cols),
        j: c.row_panels,
        k: c.split,
        order,
        real_loops: 6,
    }
}

/// π_a1 — CPU strip-mined nest {i1,i2,j1,j2,k1,k2} → unified 7 slots
/// (a unit `k3` is appended immediately after `k2`).
pub fn pi_cpu(c: &CpuConfig) -> MappedConfig {
    use Slot::*;
    // Six-slot orders per CpuOrder (outermost first), k3 inserted after k2.
    let six: [Slot; 6] = match c.order {
        CpuOrder::RowMajor => [I1, J1, K1, I2, J2, K2],
        CpuOrder::KOuter => [K1, I1, J1, I2, J2, K2],
        CpuOrder::JOuter => [J1, I1, K1, I2, J2, K2],
        CpuOrder::InnerJ => [I1, K1, J1, I2, K2, J2],
        CpuOrder::BStationary => [J1, K1, I1, J2, I2, K2],
        CpuOrder::KJOuter => [K1, J1, I1, I2, J2, K2],
        CpuOrder::KInner => [I1, J1, I2, J2, K1, K2],
        CpuOrder::Flat => [I1, I2, J1, J2, K1, K2],
    };
    let mut order = [Slot::K3; NUM_SLOTS];
    let mut w = 0;
    for s in six {
        order[w] = s;
        w += 1;
        if s == K2 {
            order[w] = K3; // Ω(k3) = Ω(k2) + 1
            w += 1;
        }
    }
    if w == 6 {
        order[6] = K3; // k2 was last: k3 appended at the end
    }
    MappedConfig { i: c.i_split, j: c.j_split, k: c.k_split, order, real_loops: 6 }
}

/// π_a3 — GPU nest {i1,i2,j,k1,k2,k3} → unified 7 slots (a unit `j'`
/// — our `J2` — is appended immediately after `j` ≡ `J1`).
///
/// The *binding* itself is heterogeneous (Table 1) and is NOT encoded
/// here; but binding determines which loop is outermost in the generated
/// kernel, so the mapped order reflects that structural consequence —
/// this is the "approximate" in approximate mapping.
pub fn pi_gpu(c: &GpuConfig) -> MappedConfig {
    use Slot::*;
    let six: [Slot; 6] = match c.binding {
        // thread-per-row: rows innermost-parallel, dense strips outer
        GpuBinding::RowPerThread => [I1, K1, I2, J1, K2, K3],
        // warp-per-row: row loop outermost, k strips within the warp
        GpuBinding::RowPerWarp => [I1, I2, J1, K1, K2, K3],
        // block-per-rowblock: k strip hoisted (block-wide tiles of B)
        GpuBinding::RowPerBlock => [K1, I1, I2, J1, K2, K3],
        // nnz-balanced: reduction split outermost (atomics combine)
        GpuBinding::NnzBalanced => [J1, I1, I2, K1, K2, K3],
    };
    let mut order = [Slot::J2; NUM_SLOTS];
    let mut w = 0;
    for s in six {
        order[w] = s;
        w += 1;
        if s == J1 {
            order[w] = J2; // Ω(j') = Ω(j) + 1
            w += 1;
        }
    }
    MappedConfig {
        i: c.i_split,
        j: 1, // GPU does not split the reduction dimension
        k: c.k1 * c.k2,
        order,
        real_loops: 6,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::space::{cpu_space, gpu_space, spade_space};

    fn is_perm(order: &[Slot; NUM_SLOTS]) -> bool {
        let mut seen = [false; NUM_SLOTS];
        for s in order {
            if seen[s.index()] {
                return false;
            }
            seen[s.index()] = true;
        }
        true
    }

    #[test]
    fn phi_barrier_selects_order() {
        let mut c = spade_space()[0];
        c.barrier = true;
        let m1 = phi_spade(&c, 4096);
        assert_eq!(m1.order[..3], [Slot::K2, Slot::J2, Slot::I2]);
        c.barrier = false;
        let m0 = phi_spade(&c, 4096);
        assert_eq!(m0.order[..3], [Slot::K2, Slot::I2, Slot::J2]);
        assert!(is_perm(&m1.order) && is_perm(&m0.order));
    }

    #[test]
    fn phi_parameter_assignment() {
        let c = SpadeConfig {
            row_panels: 32,
            col_panels: 16384,
            split: 256,
            barrier: false,
            bypass: true,
            reorder: true,
        };
        let m = phi_spade(&c, 100_000);
        assert_eq!(m.i, 16384); // I ≈ p_col
        assert_eq!(m.j, 32); // J ≈ p_row
        assert_eq!(m.k, 256); // K ≈ s_split
    }

    #[test]
    fn phi_num_matrix_cols() {
        let c = SpadeConfig {
            row_panels: 4,
            col_panels: 0,
            split: 32,
            barrier: false,
            bypass: false,
            reorder: false,
        };
        assert_eq!(phi_spade(&c, 777).i, 777);
    }

    #[test]
    fn pi_cpu_inserts_k3_after_k2() {
        for c in cpu_space().iter().step_by(17) {
            let m = pi_cpu(c);
            assert!(is_perm(&m.order), "{:?}", m.order);
            let k2 = m.order.iter().position(|s| *s == Slot::K2).unwrap();
            let k3 = m.order.iter().position(|s| *s == Slot::K3).unwrap();
            assert_eq!(k3, k2 + 1, "Ω(k3) = Ω(k2)+1 for {:?}", c.order);
        }
    }

    #[test]
    fn pi_gpu_inserts_jprime_after_j() {
        for c in gpu_space().iter().step_by(7) {
            let m = pi_gpu(c);
            assert!(is_perm(&m.order), "{:?}", m.order);
            let j1 = m.order.iter().position(|s| *s == Slot::J1).unwrap();
            let j2 = m.order.iter().position(|s| *s == Slot::J2).unwrap();
            assert_eq!(j2, j1 + 1, "Ω(j') = Ω(j)+1 for {:?}", c.binding);
            assert_eq!(m.j, 1);
            assert_eq!(m.k, c.k1 * c.k2);
        }
    }

    #[test]
    fn all_mapped_orders_are_permutations() {
        for c in spade_space() {
            assert!(is_perm(&phi_spade(c, 2048).order));
        }
    }

    #[test]
    fn mapping_is_many_to_one_but_barrier_sensitive() {
        // Two SPADE configs differing only in bypass map identically
        // (bypass is heterogeneous); differing in barrier map differently.
        let base = SpadeConfig {
            row_panels: 32,
            col_panels: 1024,
            split: 32,
            barrier: false,
            bypass: false,
            reorder: false,
        };
        let bypassed = SpadeConfig { bypass: true, ..base };
        let barriered = SpadeConfig { barrier: true, ..base };
        assert_eq!(phi_spade(&base, 4096), phi_spade(&bypassed, 4096));
        assert_ne!(phi_spade(&base, 4096), phi_spade(&barriered, 4096));
    }
}
