//! Performance-dataset collection and persistence.
//!
//! For each (platform, op) we evaluate the full config space per matrix
//! (the simulators share precomputation, so exhaustive evaluation is the
//! cheap path) and store the complete cost vector. Training then samples
//! `configs_per_matrix` entries per matrix exactly as the paper samples
//! 100 random configurations, while evaluation gets the exhaustive
//! oracle (`optimal_cost`) for free.
//!
//! Persistence is a small self-describing little-endian binary format
//! (`.cds`), since bulk f32/f64 arrays in JSON would be slow and huge.

use crate::config::PlatformId;
use crate::kernels::Op;
use crate::platform::CostModel;
use crate::sparse::features::{density_map, DMAP_LEN};
use crate::sparse::MatrixInfo;
use crate::util::pool::par_map;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// All data the cost model ever sees about one matrix.
#[derive(Clone, Debug)]
pub struct MatrixRecord {
    pub name: String,
    /// Density map (C×H×W flattened) — the featurizer input.
    pub dmap: Vec<f32>,
    /// Matrix width (resolves SPADE's NUM_MATRIX_COLS configs).
    pub cols: usize,
    pub rows: usize,
    pub nnz: usize,
    /// Cost (cycles) of *every* config in the platform's space.
    pub costs: Vec<f64>,
}

impl MatrixRecord {
    pub fn optimal_cost(&self) -> f64 {
        self.costs.iter().cloned().fold(f64::INFINITY, f64::min)
    }
    pub fn optimal_index(&self) -> usize {
        self.costs
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

#[derive(Clone, Debug)]
pub struct Dataset {
    pub platform: PlatformId,
    pub op: Op,
    pub records: Vec<MatrixRecord>,
}

impl Dataset {
    /// Collect a dataset by running the platform cost model over every
    /// matrix in parallel.
    ///
    /// Matrices are dispatched heaviest-first (LPT scheduling by nnz):
    /// with the pool's atomic-cursor work claiming, starting the big
    /// matrices early keeps the tail of the run from serializing behind
    /// one late-claimed giant. Results are scattered back so record
    /// order still matches `matrices`.
    pub fn collect(
        platform: &dyn CostModel,
        op: Op,
        matrices: &[MatrixInfo],
        threads: usize,
    ) -> Dataset {
        let mut order: Vec<usize> = (0..matrices.len()).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(matrices[i].matrix.nnz()));
        let collected = par_map(&order, threads, |_, &mi| {
            let info = &matrices[mi];
            let t_eval = std::time::Instant::now();
            let costs = platform.eval_all(&info.matrix, op);
            let eval_secs = t_eval.elapsed().as_secs_f64();
            crate::histogram!("dataset.matrix_eval_us").observe_duration(t_eval.elapsed());
            let rec = MatrixRecord {
                name: info.name.clone(),
                dmap: density_map(&info.matrix),
                cols: info.matrix.cols,
                rows: info.matrix.rows,
                nnz: info.matrix.nnz(),
                costs,
            };
            (rec, eval_secs)
        });
        // LPT dispatch skew: how much more the heaviest matrix cost than
        // the mean — the quantity LPT ordering exists to hide.
        let evals: Vec<f64> = collected.iter().map(|(_, s)| *s).collect();
        let mean = evals.iter().sum::<f64>() / evals.len().max(1) as f64;
        let max = evals.iter().cloned().fold(0.0f64, f64::max);
        if mean > 0.0 {
            crate::gauge!("dataset.lpt_skew").set(max / mean);
        }
        let mut slots: Vec<Option<MatrixRecord>> = (0..matrices.len()).map(|_| None).collect();
        for (&mi, (rec, _)) in order.iter().zip(collected) {
            slots[mi] = Some(rec);
        }
        let records = slots.into_iter().map(|s| s.expect("record collected")).collect();
        Dataset { platform: platform.id(), op, records }
    }

    /// Randomly sample `k` config indices per matrix (the paper's "100
    /// program configurations per matrix"), deterministic in `seed`.
    pub fn sample_configs(&self, k: usize, seed: u64) -> Vec<Vec<u32>> {
        let mut rng = Rng::new(seed);
        self.records
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let mut local = rng.fork(i as u64);
                let k = k.min(r.costs.len());
                local
                    .sample_indices(r.costs.len(), k)
                    .into_iter()
                    .map(|x| x as u32)
                    .collect()
            })
            .collect()
    }

    /// Split record indices into (train, val) deterministically.
    pub fn split(&self, train_frac: f64, seed: u64) -> (Vec<usize>, Vec<usize>) {
        let mut idx: Vec<usize> = (0..self.records.len()).collect();
        let mut rng = Rng::new(seed);
        rng.shuffle(&mut idx);
        let n_train = ((self.records.len() as f64) * train_frac).round() as usize;
        let val = idx.split_off(n_train.min(idx.len()));
        (idx, val)
    }

    // ---- persistence -------------------------------------------------------

    const MAGIC: &'static [u8; 8] = b"COGNDS02";

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(Self::MAGIC)?;
        w.write_all(&(self.platform.index() as u32).to_le_bytes())?;
        w.write_all(&((self.op == Op::Sddmm) as u32).to_le_bytes())?;
        w.write_all(&(self.records.len() as u64).to_le_bytes())?;
        for r in &self.records {
            let name = r.name.as_bytes();
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name)?;
            for v in [r.cols as u64, r.rows as u64, r.nnz as u64] {
                w.write_all(&v.to_le_bytes())?;
            }
            w.write_all(&(r.dmap.len() as u64).to_le_bytes())?;
            for &f in &r.dmap {
                w.write_all(&f.to_le_bytes())?;
            }
            w.write_all(&(r.costs.len() as u64).to_le_bytes())?;
            for &c in &r.costs {
                w.write_all(&c.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Dataset> {
        let mut rd =
            std::io::BufReader::new(std::fs::File::open(path).with_context(|| format!("{path:?}"))?);
        let mut magic = [0u8; 8];
        rd.read_exact(&mut magic)?;
        if &magic != Self::MAGIC {
            bail!("bad dataset magic in {path:?}");
        }
        let platform = match read_u32(&mut rd)? {
            0 => PlatformId::Cpu,
            1 => PlatformId::Spade,
            2 => PlatformId::Gpu,
            x => bail!("bad platform id {x}"),
        };
        let op = if read_u32(&mut rd)? == 1 { Op::Sddmm } else { Op::Spmm };
        let n = read_u64(&mut rd)? as usize;
        let mut records = Vec::with_capacity(n);
        for _ in 0..n {
            let name_len = read_u32(&mut rd)? as usize;
            let mut name = vec![0u8; name_len];
            rd.read_exact(&mut name)?;
            let cols = read_u64(&mut rd)? as usize;
            let rows = read_u64(&mut rd)? as usize;
            let nnz = read_u64(&mut rd)? as usize;
            let dmap_len = read_u64(&mut rd)? as usize;
            if dmap_len != DMAP_LEN {
                bail!("dmap length {dmap_len} != expected {DMAP_LEN} (stale dataset?)");
            }
            let mut dmap = vec![0f32; dmap_len];
            for v in &mut dmap {
                let mut b = [0u8; 4];
                rd.read_exact(&mut b)?;
                *v = f32::from_le_bytes(b);
            }
            let costs_len = read_u64(&mut rd)? as usize;
            let mut costs = vec![0f64; costs_len];
            for v in &mut costs {
                let mut b = [0u8; 8];
                rd.read_exact(&mut b)?;
                *v = f64::from_le_bytes(b);
            }
            records.push(MatrixRecord {
                name: String::from_utf8(name)?,
                dmap,
                cols,
                rows,
                nnz,
                costs,
            });
        }
        Ok(Dataset { platform, op, records })
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::spade::SpadeSim;
    use crate::sparse::{generate_collection, CollectionSpec};

    fn tiny_collection() -> Vec<MatrixInfo> {
        generate_collection(&CollectionSpec { seed: 5, per_cell: 1, max_dim: 384 })
            .into_iter()
            .take(4)
            .collect()
    }

    #[test]
    fn collect_and_roundtrip() {
        let coll = tiny_collection();
        let sim = SpadeSim::new();
        let ds = Dataset::collect(&sim, Op::Spmm, &coll, 2);
        assert_eq!(ds.records.len(), 4);
        for r in &ds.records {
            assert_eq!(r.costs.len(), 256);
            assert_eq!(r.dmap.len(), DMAP_LEN);
            assert!(r.optimal_cost() <= r.costs[0]);
            assert_eq!(r.costs[r.optimal_index()], r.optimal_cost());
        }
        let dir = std::env::temp_dir().join("cognate_ds_test");
        let path = dir.join("t.cds");
        ds.save(&path).unwrap();
        let back = Dataset::load(&path).unwrap();
        assert_eq!(back.platform, ds.platform);
        assert_eq!(back.op, ds.op);
        assert_eq!(back.records.len(), ds.records.len());
        for (a, b) in back.records.iter().zip(&ds.records) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.costs, b.costs);
            assert_eq!(a.dmap, b.dmap);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn collect_preserves_input_order_despite_lpt() {
        // Dispatch is heaviest-first, but records must land in input
        // order (dataset files and split indices depend on it).
        let coll = tiny_collection();
        let ds = Dataset::collect(&SpadeSim::new(), Op::Spmm, &coll, 3);
        for (info, rec) in coll.iter().zip(&ds.records) {
            assert_eq!(info.name, rec.name);
            assert_eq!(info.matrix.nnz(), rec.nnz);
        }
    }

    #[test]
    fn sampling_is_deterministic_and_distinct() {
        let coll = tiny_collection();
        let ds = Dataset::collect(&SpadeSim::new(), Op::Spmm, &coll, 2);
        let s1 = ds.sample_configs(50, 9);
        let s2 = ds.sample_configs(50, 9);
        let s3 = ds.sample_configs(50, 10);
        assert_eq!(s1, s2);
        assert_ne!(s1, s3);
        for per_mat in &s1 {
            assert_eq!(per_mat.len(), 50);
            let mut d = per_mat.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 50, "sampled configs must be distinct");
        }
    }

    #[test]
    fn split_partitions() {
        let coll = tiny_collection();
        let ds = Dataset::collect(&SpadeSim::new(), Op::Spmm, &coll, 2);
        let (tr, va) = ds.split(0.5, 3);
        assert_eq!(tr.len() + va.len(), ds.records.len());
        let mut all: Vec<usize> = tr.iter().chain(va.iter()).copied().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), ds.records.len());
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("cognate_ds_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.cds");
        std::fs::write(&path, b"not a dataset").unwrap();
        assert!(Dataset::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
