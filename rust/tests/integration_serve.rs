//! Service-level integration: telemetry consistency of the `{"stats":
//! true}` surface, the jobs-based shutdown contract, and the error
//! paths of the JSON-lines protocol. Requires `make artifacts`.
//!
//! All tests in this binary share the process-global metrics registry
//! (and the jobs/queue-wait invariant is asserted over registry
//! totals), so they serialize on one mutex and only read metrics while
//! every server they started is quiescent.

use cognate::config::PlatformId;
use cognate::coordinator::{serve, Pipeline, Scale};
use cognate::model::ModelDriver;
use cognate::train::ZEncoder;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::Duration;

static SERIAL: Mutex<()> = Mutex::new(());

fn micro_scale() -> Scale {
    let mut s = Scale::small();
    s.per_cell = 1;
    s.max_dim = 640;
    s.seed = 0xBEEF;
    s
}

/// Start a service with an untrained (but fully initialised) model —
/// scoring quality is irrelevant here, only the protocol and telemetry.
fn start_server(max_jobs: Option<usize>) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let pipe = Pipeline::new(micro_scale()).expect("artifacts present");
    let driver = ModelDriver::init(pipe.rt.clone(), "cognate", 1).unwrap();
    let (addr_tx, addr_rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        serve::serve(driver, ZEncoder::Zero, PlatformId::Spade, "127.0.0.1:0", max_jobs, move |a| {
            let _ = addr_tx.send(a);
        })
        .unwrap();
    });
    let addr = addr_rx.recv_timeout(Duration::from_secs(120)).unwrap();
    (addr, handle)
}

fn test_matrix(seed: u64) -> cognate::sparse::Csr {
    cognate::sparse::gen::generate(cognate::sparse::gen::Family::Rmat, 300, 300, 0.02, seed)
}

/// One raw protocol exchange: send `line`, read one reply line.
fn raw_roundtrip(addr: SocketAddr, line: &str) -> cognate::util::json::Json {
    let mut stream = TcpStream::connect(addr).unwrap();
    writeln!(stream, "{line}").unwrap();
    let mut reader = BufReader::new(stream);
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    cognate::util::json::Json::parse(&reply).expect("reply must be well-formed JSON")
}

#[test]
fn stats_snapshot_counters_consistent_after_serving() {
    let _g = SERIAL.lock().unwrap();
    let (addr, _server) = start_server(None);

    // Two scoring requests (sequential connections — the counts matter
    // here, not the batching).
    for id in 0..2 {
        let resp = serve::request(addr, id, 5, &test_matrix(id as u64)).unwrap();
        assert!(resp.get("error").is_none(), "server error: {}", resp.to_string());
        // Per-response stage breakdown rides along with every answer.
        let stages = resp.req("stages");
        for key in ["queue_wait_ms", "featurize_ms", "score_ms"] {
            assert!(stages.req(key).as_f64().unwrap() >= 0.0, "bad {key}");
        }
    }

    // Both replies are in hand, so the batcher recorded both jobs:
    // the snapshot must show them, and the queue-wait histogram must
    // have recorded exactly one observation per dequeued job.
    let snap = serve::request_stats(addr).unwrap();
    let jobs = snap.req("counters").req("serve.jobs_total").as_usize().unwrap();
    assert!(jobs >= 2, "jobs_total {jobs} < 2");
    let qcount = snap
        .req("histograms")
        .req("serve.queue_wait_us")
        .req("count")
        .as_usize()
        .unwrap();
    assert_eq!(qcount, jobs, "queue-wait observations must match jobs served");
    let batches = snap
        .req("histograms")
        .req("serve.batch_size")
        .req("count")
        .as_usize()
        .unwrap();
    assert!(batches >= 1 && batches <= jobs, "batches {batches} vs jobs {jobs}");
    assert!(
        snap.req("counters").req("serve.stats_requests_total").as_usize().unwrap() >= 1
    );
    // Server stays up (max_jobs: None); thread is left running and the
    // process reaps it at exit.
}

#[test]
fn max_jobs_counts_jobs_not_connections() {
    let _g = SERIAL.lock().unwrap();
    // Seed regression: the acceptor used to count *connections* against
    // the budget, so one connection issuing 3 requests left serve()
    // blocked forever waiting for 2 more connections. Now the batcher's
    // job count drives shutdown and serve() must return.
    let (addr, server) = start_server(Some(3));
    let mut stream = TcpStream::connect(addr).unwrap();
    let m = test_matrix(7);
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    for id in 0..3 {
        let mut coo = Vec::new();
        for r in 0..m.rows {
            for (&c, &v) in m.row_indices(r).iter().zip(m.row_values(r)) {
                coo.push(format!("[{r},{c},{v}]"));
            }
        }
        writeln!(
            stream,
            "{{\"id\":{id},\"k\":3,\"rows\":{},\"cols\":{},\"coo\":[{}]}}",
            m.rows,
            m.cols,
            coo.join(",")
        )
        .unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        let resp = cognate::util::json::Json::parse(&reply).unwrap();
        assert!(resp.get("error").is_none(), "job {id}: {}", resp.to_string());
        assert_eq!(resp.req("top").as_arr().unwrap().len(), 3);
    }
    drop(stream);
    // The whole service must wind down off the job budget alone.
    let (done_tx, done_rx) = mpsc::channel();
    std::thread::spawn(move || {
        let _ = server.join();
        let _ = done_tx.send(());
    });
    done_rx
        .recv_timeout(Duration::from_secs(60))
        .expect("serve() must return once max_jobs jobs are served");
}

#[test]
fn malformed_requests_get_json_error_replies() {
    let _g = SERIAL.lock().unwrap();
    let (addr, _server) = start_server(None);

    // Not JSON at all.
    let r = raw_roundtrip(addr, "this is not json");
    assert!(r.req("error").as_str().unwrap().contains("bad request"));

    // Valid JSON, missing required fields.
    let r = raw_roundtrip(addr, r#"{"id": 1, "k": 3}"#);
    assert!(r.req("error").as_str().unwrap().contains("rows"));

    // coo entry outside the declared shape.
    let r = raw_roundtrip(addr, r#"{"rows": 4, "cols": 4, "coo": [[9, 0, 1.0]]}"#);
    assert!(r.req("error").as_str().unwrap().contains("out of bounds"));

    // Errors were counted.
    let snap = serve::request_stats(addr).unwrap();
    assert!(snap.req("counters").req("serve.errors_total").as_usize().unwrap() >= 3);
}

#[test]
fn request_after_job_budget_exhausted_gets_error_reply() {
    let _g = SERIAL.lock().unwrap();
    let (addr, server) = start_server(Some(1));
    // Keep one connection open across the budget boundary.
    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // Job 1 consumes the whole budget.
    writeln!(writer, r#"{{"id":1,"k":2,"rows":2,"cols":2,"coo":[[0,0,1.0],[1,1,1.0]]}}"#)
        .unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    let resp = cognate::util::json::Json::parse(&reply).unwrap();
    assert!(resp.get("error").is_none(), "first job failed: {}", resp.to_string());

    // A second request on the same connection races the batcher's exit:
    // whichever way the race lands, the reply must be well-formed JSON
    // with an "error" field (never a hang, never a dropped connection).
    writeln!(writer, r#"{{"id":2,"k":2,"rows":2,"cols":2,"coo":[[0,1,1.0]]}}"#).unwrap();
    let mut reply2 = String::new();
    reader.read_line(&mut reply2).unwrap();
    let resp2 = cognate::util::json::Json::parse(&reply2)
        .expect("post-shutdown reply must still be JSON");
    assert!(resp2.get("error").is_some(), "expected error, got {}", resp2.to_string());

    let (done_tx, done_rx) = mpsc::channel();
    std::thread::spawn(move || {
        let _ = server.join();
        let _ = done_tx.send(());
    });
    done_rx
        .recv_timeout(Duration::from_secs(60))
        .expect("serve() must return after the budget is spent");
}
