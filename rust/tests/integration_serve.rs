//! Service-level integration: telemetry consistency of the `{"stats":
//! true}` surface, the jobs-based shutdown contract, the error paths
//! of the JSON-lines protocol, and end-to-end trace propagation
//! (client-supplied trace ids, the span tree, the `{"trace": true}`
//! export). Requires `make artifacts`.
//!
//! All tests in this binary share the process-global metrics registry
//! (and the jobs/queue-wait invariant is asserted over registry
//! totals), so they serialize on one mutex and only read metrics while
//! every server they started is quiescent.

use cognate::config::PlatformId;
use cognate::coordinator::{serve, Pipeline, Scale};
use cognate::model::ModelDriver;
use cognate::train::ZEncoder;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::Duration;

static SERIAL: Mutex<()> = Mutex::new(());

/// Start a service with an untrained (but fully initialised) model —
/// scoring quality is irrelevant here, only the protocol and telemetry.
fn start_server(
    shards: usize,
    max_jobs: Option<usize>,
) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let pipe = Pipeline::new(Scale::micro()).expect("artifacts present");
    let driver = ModelDriver::init(pipe.rt.clone(), "cognate", 1).unwrap();
    let opts = serve::ServeOpts { shards, max_jobs, ..serve::ServeOpts::default() };
    let (addr_tx, addr_rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        serve::serve(driver, ZEncoder::Zero, PlatformId::Spade, "127.0.0.1:0", opts, move |a| {
            let _ = addr_tx.send(a);
        })
        .unwrap();
    });
    let addr = addr_rx.recv_timeout(Duration::from_secs(120)).unwrap();
    (addr, handle)
}

/// Counter value from a snapshot, 0 when not yet registered.
fn counter_of(snap: &cognate::util::json::Json, name: &str) -> usize {
    snap.req("counters").get(name).and_then(|v| v.as_usize()).unwrap_or(0)
}

/// `count` of a histogram from a snapshot, 0 when not yet registered.
fn hist_count_of(snap: &cognate::util::json::Json, name: &str) -> usize {
    snap.req("histograms")
        .get(name)
        .and_then(|h| h.req("count").as_usize())
        .unwrap_or(0)
}

fn test_matrix(seed: u64) -> cognate::sparse::Csr {
    cognate::sparse::gen::generate(cognate::sparse::gen::Family::Rmat, 300, 300, 0.02, seed)
}

/// One raw protocol exchange: send `line`, read one reply line.
fn raw_roundtrip(addr: SocketAddr, line: &str) -> cognate::util::json::Json {
    let mut stream = TcpStream::connect(addr).unwrap();
    writeln!(stream, "{line}").unwrap();
    let mut reader = BufReader::new(stream);
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    cognate::util::json::Json::parse(&reply).expect("reply must be well-formed JSON")
}

#[test]
fn stats_snapshot_counters_consistent_after_serving() {
    let _g = SERIAL.lock().unwrap();
    let (addr, _server) = start_server(1, None);

    // Two scoring requests (sequential connections — the counts matter
    // here, not the batching).
    for id in 0..2 {
        let resp = serve::request(addr, id, 5, &test_matrix(id as u64)).unwrap();
        assert!(resp.get("error").is_none(), "server error: {}", resp.to_string());
        // Per-response stage breakdown rides along with every answer.
        let stages = resp.req("stages");
        for key in ["queue_wait_ms", "featurize_ms", "score_ms"] {
            assert!(stages.req(key).as_f64().unwrap() >= 0.0, "bad {key}");
        }
    }

    // Both replies are in hand, so the batcher recorded both jobs:
    // the snapshot must show them, and the queue-wait histogram must
    // have recorded exactly one observation per dequeued job.
    let snap = serve::request_stats(addr).unwrap();
    let jobs = snap.req("counters").req("serve.jobs_total").as_usize().unwrap();
    assert!(jobs >= 2, "jobs_total {jobs} < 2");
    let qcount = snap
        .req("histograms")
        .req("serve.queue_wait_us")
        .req("count")
        .as_usize()
        .unwrap();
    assert_eq!(qcount, jobs, "queue-wait observations must match jobs served");
    let batches = snap
        .req("histograms")
        .req("serve.batch_size")
        .req("count")
        .as_usize()
        .unwrap();
    assert!(batches >= 1 && batches <= jobs, "batches {batches} vs jobs {jobs}");
    assert!(
        snap.req("counters").req("serve.stats_requests_total").as_usize().unwrap() >= 1
    );
    // Server stays up (max_jobs: None); thread is left running and the
    // process reaps it at exit.
}

#[test]
fn max_jobs_counts_jobs_not_connections() {
    let _g = SERIAL.lock().unwrap();
    // Seed regression: the acceptor used to count *connections* against
    // the budget, so one connection issuing 3 requests left serve()
    // blocked forever waiting for 2 more connections. Now the batcher's
    // job count drives shutdown and serve() must return.
    let (addr, server) = start_server(1, Some(3));
    let mut stream = TcpStream::connect(addr).unwrap();
    let m = test_matrix(7);
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    for id in 0..3 {
        let mut coo = Vec::new();
        for r in 0..m.rows {
            for (&c, &v) in m.row_indices(r).iter().zip(m.row_values(r)) {
                coo.push(format!("[{r},{c},{v}]"));
            }
        }
        writeln!(
            stream,
            "{{\"id\":{id},\"k\":3,\"rows\":{},\"cols\":{},\"coo\":[{}]}}",
            m.rows,
            m.cols,
            coo.join(",")
        )
        .unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        let resp = cognate::util::json::Json::parse(&reply).unwrap();
        assert!(resp.get("error").is_none(), "job {id}: {}", resp.to_string());
        assert_eq!(resp.req("top").as_arr().unwrap().len(), 3);
    }
    drop(stream);
    // The whole service must wind down off the job budget alone.
    let (done_tx, done_rx) = mpsc::channel();
    std::thread::spawn(move || {
        let _ = server.join();
        let _ = done_tx.send(());
    });
    done_rx
        .recv_timeout(Duration::from_secs(60))
        .expect("serve() must return once max_jobs jobs are served");
}

#[test]
fn malformed_requests_get_json_error_replies() {
    let _g = SERIAL.lock().unwrap();
    let (addr, _server) = start_server(1, None);

    // Not JSON at all.
    let r = raw_roundtrip(addr, "this is not json");
    assert!(r.req("error").as_str().unwrap().contains("bad request"));

    // Valid JSON, missing required fields.
    let r = raw_roundtrip(addr, r#"{"id": 1, "k": 3}"#);
    assert!(r.req("error").as_str().unwrap().contains("rows"));

    // coo entry outside the declared shape.
    let r = raw_roundtrip(addr, r#"{"rows": 4, "cols": 4, "coo": [[9, 0, 1.0]]}"#);
    assert!(r.req("error").as_str().unwrap().contains("out of bounds"));

    // Errors were counted.
    let snap = serve::request_stats(addr).unwrap();
    assert!(snap.req("counters").req("serve.errors_total").as_usize().unwrap() >= 3);
}

#[test]
fn request_after_job_budget_exhausted_gets_error_reply() {
    let _g = SERIAL.lock().unwrap();
    let (addr, server) = start_server(1, Some(1));
    // Keep one connection open across the budget boundary.
    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // Job 1 consumes the whole budget.
    writeln!(writer, r#"{{"id":1,"k":2,"rows":2,"cols":2,"coo":[[0,0,1.0],[1,1,1.0]]}}"#)
        .unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    let resp = cognate::util::json::Json::parse(&reply).unwrap();
    assert!(resp.get("error").is_none(), "first job failed: {}", resp.to_string());

    // A second request on the same connection races the batcher's exit:
    // whichever way the race lands, the reply must be well-formed JSON
    // with an "error" field (never a hang, never a dropped connection).
    writeln!(writer, r#"{{"id":2,"k":2,"rows":2,"cols":2,"coo":[[0,1,1.0]]}}"#).unwrap();
    let mut reply2 = String::new();
    reader.read_line(&mut reply2).unwrap();
    let resp2 = cognate::util::json::Json::parse(&reply2)
        .expect("post-shutdown reply must still be JSON");
    assert!(resp2.get("error").is_some(), "expected error, got {}", resp2.to_string());

    let (done_tx, done_rx) = mpsc::channel();
    std::thread::spawn(move || {
        let _ = server.join();
        let _ = done_tx.send(());
    });
    done_rx
        .recv_timeout(Duration::from_secs(60))
        .expect("serve() must return after the budget is spent");
}

#[test]
fn traced_request_echoes_id_and_exports_nested_span_tree() {
    let _g = SERIAL.lock().unwrap();
    let (addr, _server) = start_server(2, None);

    // A client-supplied trace id forces tracing regardless of the
    // sampling knob (which defaults to 0 in this test binary — no CLI
    // init ran — so every *other* request in this file stays untraced).
    let tid: u64 = 0xC05A_7E11;
    let resp = serve::request_traced(addr, 42, 3, &test_matrix(42), tid).unwrap();
    assert!(resp.get("error").is_none(), "server error: {}", resp.to_string());
    assert_eq!(
        resp.req("trace_id").as_str(),
        Some(format!("{tid:016x}").as_str()),
        "reply must echo the client's trace id"
    );

    // The reply is written before the accept/reply spans drop on the
    // server side, so poll the rings briefly. drain() clears as it
    // reads — accumulate across polls.
    let want = [
        "serve.accept",
        "serve.parse",
        "serve.route",
        "serve.queue",
        "serve.linger",
        "serve.featurize",
        "serve.score",
        "serve.reply",
    ];
    let mut events: Vec<cognate::util::trace::SpanEvent> = Vec::new();
    for _ in 0..200 {
        events.extend(cognate::util::trace::drain().into_iter().filter(|e| e.trace_id == tid));
        if want.iter().all(|w| events.iter().any(|e| e.name == *w)) {
            break;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    let by_name = |n: &str| {
        events
            .iter()
            .find(|e| e.name == n)
            .unwrap_or_else(|| panic!("span {n} missing from trace: {events:?}"))
    };
    let accept = by_name("serve.accept");
    assert_eq!(accept.parent, 0, "accept is the root span");
    for n in &want[1..] {
        let child = by_name(n);
        assert_eq!(child.parent, accept.span_id, "{n} must parent to serve.accept");
        // Children run strictly inside the root interval: the root is
        // backdated to line arrival and only drops after the reply.
        assert!(child.start_us >= accept.start_us, "{n} starts inside the root");
        assert!(
            child.start_us + child.dur_us <= accept.start_us + accept.dur_us,
            "{n} ends inside the root"
        );
    }
    // The shard stamped its identity on the batch-phase spans.
    let shard = resp.req("shard").as_usize().unwrap() as i64;
    for n in ["serve.queue", "serve.linger", "serve.featurize", "serve.score"] {
        assert_eq!(by_name(n).arg("shard"), Some(shard), "{n} carries the shard id");
    }
    assert!(by_name("serve.linger").arg("batch").is_some(), "linger carries the batch id");

    // The live-export surface: a second traced request, then the
    // {"trace": true} control request must return Chrome trace_event
    // JSON containing it, and the control must be counted.
    let tid2: u64 = 0xC05A_7E22;
    let resp2 = serve::request_traced(addr, 43, 3, &test_matrix(43), tid2).unwrap();
    assert!(resp2.get("error").is_none());
    std::thread::sleep(Duration::from_millis(100)); // let the server-side spans drop
    let chrome = serve::request_trace(addr).unwrap();
    let list = chrome.req("traceEvents").as_arr().expect("traceEvents array");
    assert!(!list.is_empty());
    for ev in list {
        assert_eq!(ev.req("ph").as_str(), Some("X"));
        assert!(ev.req("ts").as_f64().unwrap() >= 0.0);
        assert!(ev.req("dur").as_f64().unwrap() >= 0.0);
    }
    let tid2_hex = format!("{tid2:016x}");
    assert!(
        list.iter().any(|ev| {
            ev.req("args").get("trace_id").and_then(|v| v.as_str()) == Some(tid2_hex.as_str())
        }),
        "exported trace must contain the second traced request"
    );
    let snap = serve::request_stats(addr).unwrap();
    assert!(counter_of(&snap, "serve.trace_requests_total") >= 1);
}

#[test]
fn sharded_serve_preserves_job_count_invariant() {
    let _g = SERIAL.lock().unwrap();
    let shards = 3;
    let n_jobs = 12;
    // The server runs in this process, so the before/after snapshots
    // come straight from the shared registry (deltas, because other
    // tests in this binary also serve jobs).
    let before = cognate::util::metrics::registry().snapshot();
    let (addr, _server) = start_server(shards, None);

    let clients: Vec<_> = (0..n_jobs)
        .map(|id| {
            std::thread::spawn(move || serve::request(addr, id as i64, 3, &test_matrix(id as u64)))
        })
        .collect();
    for c in clients {
        let resp = c.join().unwrap().unwrap();
        assert!(resp.get("error").is_none(), "server error: {}", resp.to_string());
        let shard = resp.req("shard").as_usize().expect("reply carries its shard index");
        assert!(shard < shards, "shard {shard} out of range");
    }

    // All replies are in hand and no other traffic exists → quiescent.
    let after = cognate::util::metrics::registry().snapshot();
    let d_jobs =
        counter_of(&after, "serve.jobs_total") - counter_of(&before, "serve.jobs_total");
    let d_qwait = hist_count_of(&after, "serve.queue_wait_us")
        - hist_count_of(&before, "serve.queue_wait_us");
    assert_eq!(d_jobs, n_jobs, "every job dequeued exactly once across shards");
    assert_eq!(d_qwait, n_jobs, "queue_wait_us.count must track jobs_total across shards");
    let d_shard_jobs: usize = (0..shards)
        .map(|i| {
            let name = format!("serve.shard_jobs_total.{i}");
            counter_of(&after, &name) - counter_of(&before, &name)
        })
        .sum();
    assert_eq!(d_shard_jobs, n_jobs, "per-shard counters must partition the job count");
    // The adaptive controller published its window for at least one shard.
    assert!(
        after.req("gauges").get("serve.linger_us").and_then(|v| v.as_f64()).unwrap_or(0.0)
            > 0.0,
        "serve.linger_us gauge must be set"
    );
}

#[test]
fn sharded_max_jobs_shutdown_contract() {
    let _g = SERIAL.lock().unwrap();
    // The job budget is global across shards: 4 jobs over 2 shards must
    // wind the whole service down, exactly like the single-shard case.
    let (addr, server) = start_server(2, Some(4));
    let clients: Vec<_> = (0..4)
        .map(|id| {
            std::thread::spawn(move || serve::request(addr, id as i64, 2, &test_matrix(id as u64)))
        })
        .collect();
    for c in clients {
        let resp = c.join().unwrap().unwrap();
        assert!(resp.get("error").is_none(), "server error: {}", resp.to_string());
    }
    let (done_tx, done_rx) = mpsc::channel();
    std::thread::spawn(move || {
        let _ = server.join();
        let _ = done_tx.send(());
    });
    done_rx
        .recv_timeout(Duration::from_secs(60))
        .expect("serve() must return once the shared budget is spent");
    // Quiescent: the global invariant holds over everything this binary
    // has served so far, shards included.
    let snap = cognate::util::metrics::registry().snapshot();
    assert_eq!(
        hist_count_of(&snap, "serve.queue_wait_us"),
        counter_of(&snap, "serve.jobs_total"),
        "queue_wait_us.count == jobs_total at quiescence"
    );
}
