//! CLI round-trip: pretrain → finetune → eval through `cli::main_inner`
//! — the checkpoint/eval path a user actually drives, at `--scale
//! micro` so the whole chain runs in seconds. Requires `make artifacts`.

use cognate::cli;

fn run(argv: &[&str]) {
    let argv: Vec<String> = argv.iter().map(|s| s.to_string()).collect();
    cli::main_inner(&argv).unwrap_or_else(|e| panic!("{} failed: {e:#}", argv.join(" ")));
}

#[test]
fn checkpoint_cli_roundtrip_pretrain_finetune_eval() {
    let tmp = std::env::temp_dir().join(format!("cognate-cli-rt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();
    let dir = tmp.to_str().unwrap();
    let pre = tmp.join("pretrained.ckpt");
    let ft = tmp.join("finetuned.ckpt");

    run(&[
        "pretrain",
        "--scale",
        "micro",
        "--results-dir",
        dir,
        "--out",
        pre.to_str().unwrap(),
    ]);
    assert!(pre.exists(), "pretrain must write its checkpoint");

    run(&[
        "finetune",
        "--ckpt",
        pre.to_str().unwrap(),
        "--target",
        "spade",
        "--scale",
        "micro",
        "--results-dir",
        dir,
        "--out",
        ft.to_str().unwrap(),
    ]);
    assert!(ft.exists(), "finetune must write its checkpoint");

    run(&[
        "eval",
        "--ckpt",
        ft.to_str().unwrap(),
        "--target",
        "spade",
        "--k",
        "5",
        "--scale",
        "micro",
        "--results-dir",
        dir,
    ]);

    // Training telemetry was persisted per epoch under the results dir:
    // 3 pretrain epochs + 2 finetune epochs at micro scale.
    let jsonl = tmp.join("metrics_epochs.jsonl");
    assert!(jsonl.exists(), "train must append metrics_epochs.jsonl");
    let text = std::fs::read_to_string(&jsonl).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 5, "one snapshot line per epoch");
    for line in &lines {
        let j = cognate::util::json::Json::parse(line).expect("snapshot line parses");
        assert!(j.req("epoch").as_usize().is_some());
        assert!(j.req("metrics").get("counters").is_some(), "snapshot JSON shape");
    }

    let _ = std::fs::remove_dir_all(&tmp);
}
