//! `cargo test -q` gate for `cognate-lint`: the repo must scan clean.
//!
//! This is the same walk `cargo run --bin cognate_lint` and the
//! `== lint ==` stage of scripts/verify.sh perform — seeding any rule
//! violation (dropping a `// SAFETY:`, adding `counter!("bogus.name")`,
//! a `format!`-named `gauge!` in a loop, …) turns this test red with
//! the exact `file:line: rule: message` diagnostic the CLI would print.

use cognate::util::lint::{find_repo_root, lint_repo};
use std::path::Path;

#[test]
fn repo_scans_clean_under_cognate_lint() {
    let root = find_repo_root(Path::new(env!("CARGO_MANIFEST_DIR")))
        .expect("repo root (rust/src + ROADMAP.md) above CARGO_MANIFEST_DIR");
    let report = lint_repo(&root).expect("lint walk must read every source file");
    // The walk must actually cover the corpus — a path regression that
    // silently scanned nothing would otherwise look like a clean repo.
    assert!(
        report.files_scanned >= 60,
        "suspiciously few files scanned ({}) — did the scan roots move?",
        report.files_scanned
    );
    assert!(
        report.findings.is_empty(),
        "cognate-lint findings at HEAD:\n{}\n({} findings, {} files scanned)",
        report.render(),
        report.findings.len(),
        report.files_scanned
    );
}

#[test]
fn lint_json_summary_is_machine_readable() {
    let root = find_repo_root(Path::new(env!("CARGO_MANIFEST_DIR"))).expect("repo root");
    let report = lint_repo(&root).expect("lint walk");
    let json = report.to_json().to_string();
    let back = cognate::util::json::Json::parse(&json).expect("summary must parse");
    assert_eq!(back.req("ok").as_bool(), Some(report.findings.is_empty()));
    assert_eq!(
        back.req("files_scanned").as_f64(),
        Some(report.files_scanned as f64)
    );
}
