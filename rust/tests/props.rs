//! Randomized property-style tests over the L3 substrates.
//!
//! `proptest` is not available in the offline vendor set, so these use
//! the crate's own seeded PRNG: each test draws many random instances
//! and asserts invariants — same discipline, reproducible by seed.

use cognate::config::{self, Config};
use cognate::kernels::{sddmm_ref, sddmm_scheduled, spmm_ref, spmm_scheduled, SddmmSchedule, SpmmSchedule};
use cognate::platform::tiles::{makespan, tile_grid};
use cognate::sparse::csr::Csr;
use cognate::sparse::gen::{generate, Family, ALL_FAMILIES};
use cognate::sparse::reorder::{apply, permutation, ALL_REORDERS};
use cognate::util::json::Json;
use cognate::util::rng::Rng;

fn random_matrix(rng: &mut Rng) -> Csr {
    let fam = *rng.choose(&ALL_FAMILIES);
    let rows = 16 + rng.next_usize(400);
    let cols = 16 + rng.next_usize(400);
    let density = 10f64.powf(rng.range_f64(-2.5, -0.8));
    generate(fam, rows, cols, density, rng.next_u64())
}

#[test]
fn prop_from_coo_always_valid_with_duplicates() {
    let mut rng = Rng::new(101);
    for _ in 0..50 {
        let rows = 1 + rng.next_usize(64);
        let cols = 1 + rng.next_usize(64);
        let n = rng.next_usize(300);
        let coo: Vec<(u32, u32, f32)> = (0..n)
            .map(|_| (rng.next_usize(rows) as u32, rng.next_usize(cols) as u32, rng.next_f32()))
            .collect();
        let total: f64 = coo.iter().map(|&(_, _, v)| v as f64).sum();
        let m = Csr::from_coo(rows, cols, coo);
        m.validate().unwrap();
        // Value mass conserved under duplicate merging.
        let mass: f64 = m.values.iter().map(|&v| v as f64).sum();
        assert!((mass - total).abs() < 1e-3 * (1.0 + total.abs()), "{mass} vs {total}");
    }
}

#[test]
fn prop_transpose_involution_and_permute_preserves_rows() {
    let mut rng = Rng::new(102);
    for _ in 0..20 {
        let m = random_matrix(&mut rng);
        assert_eq!(m.transpose().transpose(), m);
        for &s in &ALL_REORDERS {
            let p = permutation(&m, s);
            let pm = apply(&m, s);
            pm.validate().unwrap();
            // Each output row is exactly the claimed input row.
            for (new_r, &old_r) in p.iter().enumerate() {
                assert_eq!(pm.row_indices(new_r), m.row_indices(old_r));
            }
        }
    }
}

#[test]
fn prop_spmm_schedules_equal_oracle() {
    let mut rng = Rng::new(103);
    for _ in 0..12 {
        let m = random_matrix(&mut rng);
        let n = 1 + rng.next_usize(48);
        let b: Vec<f32> = (0..m.cols * n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let mut expect = vec![0f32; m.rows * n];
        spmm_ref(&m, &b, n, &mut expect);
        let s = SpmmSchedule {
            i_block: 1 + rng.next_usize(300),
            k_block: 1 + rng.next_usize(64),
            outer_k: rng.next_f64() < 0.5,
        };
        let mut got = vec![0f32; m.rows * n];
        spmm_scheduled(&m, &b, n, s, &mut got);
        for (g, e) in got.iter().zip(&expect) {
            assert!((g - e).abs() <= 1e-4 * (1.0 + e.abs()), "{s:?}");
        }
    }
}

#[test]
fn prop_sddmm_schedules_equal_oracle() {
    let mut rng = Rng::new(104);
    for _ in 0..12 {
        let m = random_matrix(&mut rng);
        let k = 1 + rng.next_usize(48);
        let b: Vec<f32> = (0..m.rows * k).map(|_| rng.next_f32() - 0.5).collect();
        let c: Vec<f32> = (0..k * m.cols).map(|_| rng.next_f32() - 0.5).collect();
        let mut expect = vec![0f32; m.nnz()];
        sddmm_ref(&m, &b, &c, k, &mut expect);
        let s = SddmmSchedule {
            i_block: 1 + rng.next_usize(200),
            k_block: 1 + rng.next_usize(64),
            outer_k: rng.next_f64() < 0.5,
        };
        let mut got = vec![0f32; m.nnz()];
        sddmm_scheduled(&m, &b, &c, k, s, &mut got);
        for (g, e) in got.iter().zip(&expect) {
            assert!((g - e).abs() <= 1e-3 * (1.0 + e.abs()), "{s:?}");
        }
    }
}

#[test]
fn prop_tile_grid_conserves_nnz_and_bounds_ucols() {
    let mut rng = Rng::new(105);
    for _ in 0..30 {
        let m = random_matrix(&mut rng);
        let rp = 1 + rng.next_usize(m.rows + 10);
        let cp = 1 + rng.next_usize(m.cols + 10);
        let g = tile_grid(&m, rp, cp);
        assert_eq!(g.tiles.iter().map(|t| t.nnz as usize).sum::<usize>(), m.nnz());
        for t in &g.tiles {
            assert!(t.ucols <= t.nnz);
            assert!(t.ucols as usize <= g.col_panel);
        }
        assert_eq!(g.panel_rows.iter().map(|&r| r as usize).sum::<usize>(), m.rows);
    }
}

#[test]
fn prop_makespan_bounds() {
    let mut rng = Rng::new(106);
    for _ in 0..60 {
        let n = 1 + rng.next_usize(50);
        let w = 1 + rng.next_usize(16);
        let costs: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 100.0)).collect();
        let (mk, mean) = makespan(&costs, w);
        let mx = costs.iter().cloned().fold(0.0f64, f64::max);
        let total: f64 = costs.iter().sum();
        assert!(mk + 1e-9 >= mean, "makespan below mean");
        assert!(mk + 1e-9 >= mx, "makespan below max job");
        assert!(mk <= total + 1e-9, "makespan above serial time");
    }
}

#[test]
fn prop_encodings_deterministic_and_sized() {
    let mut rng = Rng::new(107);
    let spaces: Vec<Config> = config::cpu_space()
        .iter()
        .copied()
        .map(Config::Cpu)
        .chain(config::spade_space().iter().copied().map(Config::Spade))
        .chain(config::gpu_space().iter().copied().map(Config::Gpu))
        .collect();
    for _ in 0..200 {
        let cfg = spaces[rng.next_usize(spaces.len())];
        let cols = 16 + rng.next_usize(100_000);
        let m1 = config::mapped_vector(&cfg, cols);
        let m2 = config::mapped_vector(&cfg, cols);
        assert_eq!(m1, m2);
        assert_eq!(m1.len(), config::MAPPED_DIM);
        assert_eq!(config::het_vector(&cfg).len(), config::HET_DIM);
        assert_eq!(config::fa_vector(&cfg, cols).len(), config::FA_DIM);
        // All features bounded — no exploding inputs for the model.
        for &v in m1.iter() {
            assert!((0.0..=1.5).contains(&v), "mapped feature out of range: {v}");
        }
    }
}

#[test]
fn prop_platform_costs_scale_sanely() {
    // Costs must be positive, finite, and monotone-ish in problem size.
    use cognate::kernels::Op;
    use cognate::platform::{make_platform, CostModel};
    let mut rng = Rng::new(108);
    for id in [config::PlatformId::Cpu, config::PlatformId::Spade, config::PlatformId::Gpu] {
        let p = make_platform(id);
        for _ in 0..4 {
            let m = random_matrix(&mut rng);
            let costs = p.eval_all(&m, Op::Spmm);
            assert_eq!(costs.len(), p.num_configs());
            assert!(costs.iter().all(|c| c.is_finite() && *c > 0.0), "{id:?}");
        }
        // 4x the nnz at the same shape should not be cheaper at default.
        let small = generate(Family::Uniform, 600, 600, 0.004, 9);
        let big = generate(Family::Uniform, 600, 600, 0.016, 9);
        let cs = p.eval_all(&small, Op::Spmm)[p.default_index()];
        let cb = p.eval_all(&big, Op::Spmm)[p.default_index()];
        assert!(cb > cs, "{id:?}: {cb} !> {cs}");
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    let mut rng = Rng::new(109);
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.next_usize(4) } else { rng.next_usize(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f64() < 0.5),
            2 => Json::Num((rng.next_f64() * 2e6).round() / 100.0 - 1e4),
            3 => Json::Str(
                (0..rng.next_usize(12))
                    .map(|_| *rng.choose(&['a', 'ß', '"', '\\', '\n', 'z', '💡', ' ']))
                    .collect(),
            ),
            4 => Json::Arr((0..rng.next_usize(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.next_usize(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for _ in 0..300 {
        let v = random_json(&mut rng, 3);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap_or_else(|e| panic!("parse {s:?}: {e}"));
        assert_eq!(back, v, "roundtrip failed for {s}");
        let pretty = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(pretty, v);
    }
}

#[test]
fn prop_density_map_bounded_and_deterministic() {
    use cognate::sparse::features::{density_map, DMAP_LEN};
    let mut rng = Rng::new(110);
    for _ in 0..20 {
        let m = random_matrix(&mut rng);
        let d1 = density_map(&m);
        let d2 = density_map(&m);
        assert_eq!(d1, d2);
        assert_eq!(d1.len(), DMAP_LEN);
        assert!(d1.iter().all(|&v| (0.0..=1.001).contains(&v)));
    }
}

#[test]
fn prop_metrics_snapshot_parseable_sorted_and_bucket_consistent() {
    use cognate::util::metrics::{canon_kind, Kind, Registry, CANON};
    let mut rng = Rng::new(111);
    for _round in 0..25 {
        // Fresh private registry per round; metrics drawn from CANON
        // (instanced `<i>` templates made concrete), random values.
        let r = Registry::new();
        let mut hist_names = Vec::new();
        for _ in 0..1 + rng.next_usize(CANON.len()) {
            let (tmpl, _) = *rng.choose(CANON);
            let name = tmpl.replace("<i>", &rng.next_usize(8).to_string());
            // Duplicate draws re-register the same kind — idempotent.
            match canon_kind(tmpl) {
                Some(Kind::Counter) => r.counter(&name).add(rng.next_u64() >> 40),
                Some(Kind::Gauge) => r.gauge(&name).set(rng.range_f64(-1e6, 1e6)),
                Some(Kind::Histogram) => {
                    let h = r.histogram(&name);
                    for _ in 0..rng.next_usize(200) {
                        h.observe(rng.next_u64() >> (rng.next_usize(63) as u32));
                    }
                    hist_names.push(name);
                }
                None => unreachable!("CANON entry must resolve"),
            }
        }
        // Snapshot is parseable JSON and a fixed point of parse∘print
        // (util::json prints BTreeMap objects, so keys are sorted).
        let s = r.snapshot().to_string();
        let back = Json::parse(&s).unwrap_or_else(|e| panic!("snapshot {s:?}: {e}"));
        assert_eq!(back.to_string(), s, "snapshot must round-trip byte-identically");
        // Sorted keys, verified against the raw string: each section's
        // quoted keys appear in strictly increasing byte offsets.
        for section in ["counters", "gauges", "histograms"] {
            if let Some(Json::Obj(map)) = back.get(section) {
                let mut last = 0usize;
                for key in map.keys() {
                    let needle = format!("\"{key}\"");
                    let at = s[last..].find(&needle).map(|i| last + i).unwrap_or_else(|| {
                        panic!("{section} key {key} out of order in {s}")
                    });
                    last = at + needle.len();
                }
            }
        }
        // Histogram invariant: count == sum of bucket counts, and the
        // snapshot's count field agrees with the handle.
        for name in &hist_names {
            let h = r.histogram(name);
            assert_eq!(
                h.bucket_counts().iter().sum::<u64>(),
                h.count(),
                "{name}: bucket counts must sum to count"
            );
            let snap_count = back
                .get("histograms")
                .and_then(|hs| hs.get(name))
                .and_then(|o| o.get("count"))
                .and_then(|c| c.as_f64())
                .unwrap_or_else(|| panic!("{name} missing from snapshot {s}"));
            assert_eq!(snap_count as u64, h.count());
        }
    }
}
