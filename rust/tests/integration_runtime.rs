//! Integration tests over real AOT artifacts (requires `make artifacts`).

use cognate::model::{AeDriver, ModelDriver, TrainBatch};
use cognate::runtime::{artifacts_dir, Runtime};
use cognate::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

fn runtime() -> Arc<Runtime> {
    Arc::new(Runtime::load(&artifacts_dir()).expect("run `make artifacts` first"))
}

fn random_batch(d: &ModelDriver, seed: u64) -> TrainBatch {
    let mut rng = Rng::new(seed);
    let b = d.train_b();
    let mk = |n: usize, rng: &mut Rng| (0..n).map(|_| rng.next_f32()).collect::<Vec<_>>();
    TrainBatch {
        dmap: mk(b * d.dmap_len(), &mut rng),
        cfg_a: mk(b * d.cfg_dim, &mut rng),
        z_a: mk(b * d.latent_dim(), &mut rng),
        cfg_b: mk(b * d.cfg_dim, &mut rng),
        z_b: mk(b * d.latent_dim(), &mut rng),
        sign: (0..b).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect(),
        weight: vec![1.0; b],
    }
}

#[test]
fn init_train_score_roundtrip_and_latency() {
    let rt = runtime();
    let mut d = ModelDriver::init(rt.clone(), "cognate", 0).unwrap();
    let batch = random_batch(&d, 1);
    // Warm-up (compiles the artifact).
    let l0 = d.train_step(&batch).unwrap();
    assert!(l0.is_finite());
    let t0 = Instant::now();
    let mut last = l0;
    for _ in 0..5 {
        last = d.train_step(&batch).unwrap();
    }
    let per_step = t0.elapsed().as_secs_f64() / 5.0;
    eprintln!("train_step latency: {:.1} ms (loss {l0:.4} -> {last:.4})", per_step * 1e3);
    assert!(last <= l0 * 1.5, "loss exploding: {l0} -> {last}");

    // featurize + score
    let dmap: Vec<f32> = (0..d.dmap_len()).map(|i| (i % 7) as f32 / 7.0).collect();
    let t1 = Instant::now();
    let s = d.featurize(&[&dmap]).unwrap().remove(0);
    eprintln!("featurize latency: {:.1} ms", t1.elapsed().as_secs_f64() * 1e3);
    assert_eq!(s.len(), d.embed_dim());
    let n = 256;
    let cfgs: Vec<f32> = (0..n * d.cfg_dim).map(|i| (i % 5) as f32 / 5.0).collect();
    let zs: Vec<f32> = (0..n * d.latent_dim()).map(|i| (i % 3) as f32 / 3.0).collect();
    let t2 = Instant::now();
    let scores = d.score_configs(&s, &cfgs, &zs).unwrap();
    eprintln!("score 256 configs: {:.1} ms", t2.elapsed().as_secs_f64() * 1e3);
    assert_eq!(scores.len(), n);
    assert!(scores.iter().all(|x| x.is_finite()));
}

#[test]
fn ae_train_and_encode() {
    let rt = runtime();
    let mut ae = AeDriver::init(rt.clone(), "ae", 0).unwrap();
    let b = rt.dim("SCORE_B");
    let hd = rt.dim("HET_DIM");
    let lat = rt.dim("LATENT_DIM");
    let mut rng = Rng::new(2);
    let x: Vec<f32> = (0..b * hd).map(|_| if rng.next_f64() > 0.5 { 1.0 } else { 0.0 }).collect();
    let eps = vec![0f32; b * lat];
    let first = ae.train_step(&x, &eps).unwrap();
    let mut last = first;
    for _ in 0..60 {
        last = ae.train_step(&x, &eps).unwrap();
    }
    assert!(last < first, "ae not learning: {first} -> {last}");
    let z = ae.encode(&x[..3 * hd]).unwrap();
    assert_eq!(z.len(), 3 * lat);
}

#[test]
fn init_deterministic_per_seed() {
    let rt = runtime();
    let a = ModelDriver::init(rt.clone(), "waco_fm", 7).unwrap();
    let b = ModelDriver::init(rt.clone(), "waco_fm", 7).unwrap();
    let c = ModelDriver::init(rt.clone(), "waco_fm", 8).unwrap();
    assert_eq!(a.theta, b.theta);
    assert_ne!(a.theta, c.theta);
}
