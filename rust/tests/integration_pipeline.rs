//! End-to-end pipeline integration (micro scale): collection →
//! simulators → datasets → AE → pre-train → few-shot fine-tune →
//! top-k evaluation, plus the batched tuning service. Requires
//! `make artifacts`.

use cognate::config::PlatformId;
use cognate::coordinator::{serve, Pipeline, Scale};
use cognate::kernels::Op;
use cognate::model::ModelDriver;
use cognate::search::{evaluate, oracle_summary};
use cognate::train::{train, ZEncoder};

fn micro_scale() -> Scale {
    // The smallest runnable shape lives in the library now so the CLI
    // (`--scale micro`) and these tests stay in lockstep.
    Scale::micro()
}

#[test]
fn micro_pipeline_pretrain_finetune_evaluate() {
    let mut pipe = Pipeline::new(micro_scale()).expect("artifacts present");
    pipe.results_dir = std::env::temp_dir().join("cognate_it_results");
    let op = Op::Spmm;

    // Source + target datasets through the simulators.
    let src = pipe.dataset(PlatformId::Cpu, op).unwrap();
    let tgt = pipe.dataset(PlatformId::Spade, op).unwrap();
    assert_eq!(src.records.len(), tgt.records.len());
    assert_eq!(tgt.records[0].costs.len(), 256);

    // Latent encoders.
    let z_src = pipe.trained_ae(PlatformId::Cpu, "ae", 1).unwrap();
    let z_tgt = pipe.trained_ae(PlatformId::Spade, "ae", 2).unwrap();

    // Pre-train on CPU.
    let (src_pool, _) = pipe.splits(&src);
    let idx = pipe.pretrain_subset(&src, &src_pool, pipe.scale.pretrain_matrices);
    let mut driver = ModelDriver::init(pipe.rt.clone(), "cognate", 0).unwrap();
    let logs = train(&mut driver, &z_src, &src, &idx, &[], &pipe.scale.pretrain_opts.clone()).unwrap();
    assert!(!logs.is_empty());
    assert!(logs.iter().all(|l| l.train_loss.is_finite()));
    // Loss should drop from the first epoch to the best epoch.
    let best = logs.iter().map(|l| l.train_loss).fold(f64::INFINITY, f64::min);
    assert!(best < logs[0].train_loss + 1e-9, "no training progress");

    // Fine-tune on SPADE with 3 matrices and evaluate.
    let (pool, eval_idx) = pipe.splits(&tgt);
    let ft: Vec<usize> = pool.into_iter().take(3).collect();
    let mut tuned = driver.fork_for_finetune();
    train(&mut tuned, &z_tgt, &tgt, &ft, &[], &pipe.scale.finetune_opts.clone()).unwrap();
    let default_index = cognate::config::default_config_index(PlatformId::Spade);
    let top5 = evaluate(&tuned, &z_tgt, &tgt, &eval_idx, default_index, 5).unwrap();
    let oracle = oracle_summary(&tgt, &eval_idx, default_index);
    assert!(top5.geomean_speedup.is_finite() && top5.geomean_speedup > 0.0);
    assert!(
        top5.geomean_speedup <= oracle.geomean_speedup + 1e-9,
        "cannot beat the oracle"
    );
    // Even a micro-trained model should not be catastrophically below
    // the default config with top-5 safety.
    assert!(
        top5.geomean_speedup > 0.5,
        "speedup collapsed: {}",
        top5.geomean_speedup
    );
}

#[test]
fn tuning_service_round_trip() {
    let pipe = Pipeline::new(micro_scale()).expect("artifacts present");
    let driver = ModelDriver::init(pipe.rt.clone(), "cognate", 1).unwrap();
    let zenc = ZEncoder::Zero;
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let server = std::thread::spawn(move || {
        serve::serve(
            driver,
            zenc,
            PlatformId::Spade,
            "127.0.0.1:0",
            serve::ServeOpts::with_max_jobs(Some(3)),
            move |a| {
                let _ = addr_tx.send(a);
            },
        )
        .unwrap();
    });
    let addr = addr_rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();

    // Three concurrent clients — exercises the dynamic batcher.
    let mut clients = Vec::new();
    for id in 0..3 {
        clients.push(std::thread::spawn(move || {
            let m = cognate::sparse::gen::generate(
                cognate::sparse::gen::Family::Rmat,
                300,
                300,
                0.02,
                id as u64,
            );
            serve::request(addr, id, 5, &m).unwrap()
        }));
    }
    for c in clients {
        let resp = c.join().unwrap();
        assert!(resp.get("error").is_none(), "server error: {}", resp.to_string());
        let top = resp.req("top").as_arr().unwrap();
        assert_eq!(top.len(), 5);
        for t in top {
            assert!(t.as_usize().unwrap() < 256);
        }
        assert!(resp.req("latency_ms").as_f64().unwrap() >= 0.0);
    }
    let _ = server; // server exits after max_jobs connections
}
