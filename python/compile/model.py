"""L2: the COGNATE cost model family in JAX (build-time only).

Model variants (all sharing the same AOT signature so the Rust driver is
variant-agnostic):

* ``cognate``  — full model (Fig 3b): input featurizer (multi-scale conv
  pyramid), configuration mapper (MLP over the φ/π-mapped homogeneous
  vector), latent vector z from the per-target autoencoder, MLP
  predictor.
* ``noife`` / ``nofm`` / ``nole`` — Fig 7 component ablations (drop the
  featurizer / configuration mapper / latent encoder respectively).
* ``tf`` / ``gru`` — Fig 8 predictor ablations (tiny self-attention /
  gated-recurrent combine instead of the MLP predictor).
* ``waco_fa`` / ``waco_fm`` — WacoNet baselines: fixed-width featurizer
  plus a program embedder over the feature-augmented (FA) or
  feature-mapped (FM) raw config vector; no latent path.

Parameters travel as ONE flat f32 vector (``ravel_pytree``), so the Rust
runtime manages exactly three mutable buffers (θ, Adam m, Adam v).

Every dense layer and conv goes through the L1 Pallas kernels
(``matmul_fused`` / ``conv2d``); the ranking loss is the L1 hinge kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from . import dims
from .kernels.conv2d import conv2d, global_avg_pool, maxpool2x2
from .kernels.matmul import matmul_fused
from .kernels.ranking import ranking_loss

VARIANTS = ("cognate", "noife", "nofm", "nole", "tf", "gru", "waco_fa", "waco_fm")

# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------


def _linear_params(key, fan_in, fan_out):
    wk, _ = jax.random.split(key)
    scale = jnp.sqrt(2.0 / fan_in)
    return {
        "w": jax.random.normal(wk, (fan_in, fan_out), jnp.float32) * scale,
        "b": jnp.zeros((fan_out,), jnp.float32),
    }


def _conv_params(key, ksize, cin, cout):
    wk, _ = jax.random.split(key)
    scale = jnp.sqrt(2.0 / (ksize * ksize * cin))
    return {
        "w": jax.random.normal(wk, (ksize, ksize, cin, cout), jnp.float32) * scale,
        "b": jnp.zeros((cout,), jnp.float32),
    }


def _mlp_params(key, sizes):
    keys = jax.random.split(key, len(sizes) - 1)
    return [_linear_params(k, a, b) for k, a, b in zip(keys, sizes[:-1], sizes[1:])]


def _mlp(params, x, final_relu=False):
    for i, layer in enumerate(params):
        relu = final_relu or i + 1 < len(params)
        x = matmul_fused(x, layer["w"], layer["b"], relu)
    return x


def _cfg_dim(variant):
    return dims.FA_DIM if variant == "waco_fa" else dims.MAPPED_DIM


def _uses_featurizer(variant):
    return variant != "noife"


def _uses_mapper(variant):
    return variant != "nofm"


def _uses_latent(variant):
    return variant not in ("nole", "waco_fa", "waco_fm")


def init_params(variant, key):
    """Parameter pytree for a model variant."""
    assert variant in VARIANTS, variant
    keys = jax.random.split(key, 8)
    p = {}
    if _uses_featurizer(variant):
        if variant.startswith("waco"):
            # WACO: fixed-width stack, single-scale readout.
            convs = []
            cin = dims.DMAP_C
            ck = jax.random.split(keys[0], dims.WACO_LAYERS)
            for i in range(dims.WACO_LAYERS):
                ksize = 5 if i == 0 else 3
                convs.append(_conv_params(ck[i], ksize, cin, dims.WACO_CHANNELS))
                cin = dims.WACO_CHANNELS
            p["feat"] = {
                "convs": convs,
                "proj": _linear_params(keys[1], dims.WACO_CHANNELS, dims.EMBED_DIM),
            }
        else:
            # COGNATE: rising widths, multi-scale readout (GAP per block).
            convs = []
            cin = dims.DMAP_C
            ck = jax.random.split(keys[0], sum(len(b) for b in dims.FEAT_BLOCKS))
            ki = 0
            for bi, block in enumerate(dims.FEAT_BLOCKS):
                for li, cout in enumerate(block):
                    ksize = 5 if (bi == 0 and li == 0) else 3
                    convs.append(_conv_params(ck[ki], ksize, cin, cout))
                    cin = cout
                    ki += 1
            multi = sum(b[-1] for b in dims.FEAT_BLOCKS)
            p["feat"] = {
                "convs": convs,
                "proj": _linear_params(keys[1], multi, dims.EMBED_DIM),
            }
    if _uses_mapper(variant):
        in_dim = _cfg_dim(variant)
        p["mapper"] = _mlp_params(keys[2], (in_dim, 64, dims.CFG_EMBED))
    pred_in = 0
    if _uses_featurizer(variant):
        pred_in += dims.EMBED_DIM
    if _uses_mapper(variant):
        pred_in += dims.CFG_EMBED
    if _uses_latent(variant):
        pred_in += dims.LATENT_DIM
    if variant == "tf":
        p["tok"] = {
            "s": _linear_params(keys[3], dims.EMBED_DIM, 64),
            "p": _linear_params(keys[4], dims.CFG_EMBED, 64),
            "z": _linear_params(keys[5], dims.LATENT_DIM, 64),
        }
        p["attn"] = {
            "q": _linear_params(jax.random.fold_in(keys[6], 0), 64, 64),
            "k": _linear_params(jax.random.fold_in(keys[6], 1), 64, 64),
            "v": _linear_params(jax.random.fold_in(keys[6], 2), 64, 64),
        }
        p["pred"] = _mlp_params(keys[7], (64, 64, 1))
    elif variant == "gru":
        p["tok"] = {
            "s": _linear_params(keys[3], dims.EMBED_DIM, 64),
            "p": _linear_params(keys[4], dims.CFG_EMBED, 64),
            "z": _linear_params(keys[5], dims.LATENT_DIM, 64),
        }
        p["gru"] = {
            "xz": _linear_params(jax.random.fold_in(keys[6], 0), 64, 64),
            "hz": _linear_params(jax.random.fold_in(keys[6], 1), 64, 64),
            "xr": _linear_params(jax.random.fold_in(keys[6], 2), 64, 64),
            "hr": _linear_params(jax.random.fold_in(keys[6], 3), 64, 64),
            "xh": _linear_params(jax.random.fold_in(keys[6], 4), 64, 64),
            "hh": _linear_params(jax.random.fold_in(keys[6], 5), 64, 64),
        }
        p["pred"] = _mlp_params(keys[7], (64, 64, 1))
    else:
        # MLP predictor (paper Table 6 shape, widened to the concat dim).
        p["pred"] = _mlp_params(keys[7], (pred_in, 128, 64, 1))
    return p


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def featurize(variant, params, dmap):
    """Density map [B, C, H, W] -> matrix embedding s_M [B, EMBED_DIM]."""
    if not _uses_featurizer(variant):
        return jnp.zeros((dmap.shape[0], dims.EMBED_DIM), jnp.float32)
    feat = params["feat"]
    x = jnp.transpose(dmap, (0, 2, 3, 1))  # NHWC
    if variant.startswith("waco"):
        for i, conv in enumerate(feat["convs"]):
            x = conv2d(x, conv["w"], conv["b"], relu=True)
            if i % 3 == 2 and x.shape[1] >= 2:
                x = maxpool2x2(x)
        readout = global_avg_pool(x)
    else:
        scales = []
        ci = 0
        for block in dims.FEAT_BLOCKS:
            for _ in block:
                conv = feat["convs"][ci]
                x = conv2d(x, conv["w"], conv["b"], relu=True)
                ci += 1
            scales.append(global_avg_pool(x))  # multi-scale readout
            x = maxpool2x2(x)
        readout = jnp.concatenate(scales, axis=-1)
    return matmul_fused(readout, feat["proj"]["w"], feat["proj"]["b"], False)


def _head(variant, params, s, cfg, z):
    """(s_M, mapped-config, latent) -> scalar score per row."""
    parts = []
    if _uses_featurizer(variant):
        parts.append(s)
    p_vec = None
    if _uses_mapper(variant):
        p_vec = _mlp(params["mapper"], cfg)
        parts.append(p_vec)
    if _uses_latent(variant):
        parts.append(z)

    if variant == "tf":
        toks = jnp.stack(
            [
                _mlp([params["tok"]["s"]], s),
                _mlp([params["tok"]["p"]], p_vec),
                _mlp([params["tok"]["z"]], z),
            ],
            axis=1,
        )  # [B, 3, 64]
        b = toks.shape[0]
        flat = toks.reshape(b * 3, 64)
        q = _mlp([params["attn"]["q"]], flat).reshape(b, 3, 64)
        k = _mlp([params["attn"]["k"]], flat).reshape(b, 3, 64)
        v = _mlp([params["attn"]["v"]], flat).reshape(b, 3, 64)
        logits = jnp.einsum("btd,bsd->bts", q, k) / jnp.sqrt(64.0)
        attn = jax.nn.softmax(logits, axis=-1)
        mixed = jnp.einsum("bts,bsd->btd", attn, v).mean(axis=1)
        return _mlp(params["pred"], mixed)[:, 0]
    if variant == "gru":
        toks = [
            _mlp([params["tok"]["s"]], s),
            _mlp([params["tok"]["p"]], p_vec),
            _mlp([params["tok"]["z"]], z),
        ]
        g = params["gru"]
        h = jnp.zeros_like(toks[0])
        for x_t in toks:
            zt = jax.nn.sigmoid(_mlp([g["xz"]], x_t) + _mlp([g["hz"]], h))
            rt = jax.nn.sigmoid(_mlp([g["xr"]], x_t) + _mlp([g["hr"]], h))
            ht = jnp.tanh(_mlp([g["xh"]], x_t) + _mlp([g["hh"]], rt * h))
            h = (1.0 - zt) * h + zt * ht
        return _mlp(params["pred"], h)[:, 0]
    return _mlp(params["pred"], jnp.concatenate(parts, axis=-1))[:, 0]


def score_cached(variant, params, s, cfg, z):
    """Score a batch given precomputed matrix embeddings."""
    return _head(variant, params, s, cfg, z)


def score(variant, params, dmap, cfg, z):
    return _head(variant, params, featurize(variant, params, dmap), cfg, z)


# ---------------------------------------------------------------------------
# Training (Adam, pairwise margin ranking)
# ---------------------------------------------------------------------------


def adam_update(theta, m, v, g, step, lr):
    m = dims.ADAM_B1 * m + (1.0 - dims.ADAM_B1) * g
    v = dims.ADAM_B2 * v + (1.0 - dims.ADAM_B2) * g * g
    mhat = m / (1.0 - dims.ADAM_B1**step)
    vhat = v / (1.0 - dims.ADAM_B2**step)
    theta = theta - lr * mhat / (jnp.sqrt(vhat) + dims.ADAM_EPS)
    return theta, m, v


def make_flat_fns(variant):
    """Build (theta_len, init_flat, featurize_flat, score_cached_flat,
    train_step_flat) — the flat-θ entry points aot.py lowers."""
    template = init_params(variant, jax.random.PRNGKey(0))
    flat0, unravel = ravel_pytree(template)
    theta_len = flat0.shape[0]

    def init_flat(seed):
        params = init_params(variant, jax.random.PRNGKey(seed))
        return (ravel_pytree(params)[0],)

    def featurize_flat(theta, dmap):
        return (featurize(variant, unravel(theta), dmap),)

    def score_cached_flat(theta, s, cfg, z):
        return (score_cached(variant, unravel(theta), s, cfg, z),)

    def train_step_flat(theta, m, v, step, dmap, cfg_a, z_a, cfg_b, z_b, sign, weight):
        def loss_fn(th):
            params = unravel(th)
            s = featurize(variant, params, dmap)
            ra = _head(variant, params, s, cfg_a, z_a)
            rb = _head(variant, params, s, cfg_b, z_b)
            return ranking_loss(ra, rb, sign, weight, dims.MARGIN)

        loss, g = jax.value_and_grad(loss_fn)(theta)
        theta2, m2, v2 = adam_update(theta, m, v, g, step, dims.LR)
        return theta2, m2, v2, loss

    return theta_len, init_flat, featurize_flat, score_cached_flat, train_step_flat


# ---------------------------------------------------------------------------
# Autoencoders for the heterogeneous component (§3.3, Fig 9)
# ---------------------------------------------------------------------------

AE_KINDS = ("ae", "vae")


def init_ae(kind, key):
    k1, k2 = jax.random.split(key)
    enc_out = dims.LATENT_DIM * (2 if kind == "vae" else 1)
    return {
        "enc": _mlp_params(k1, (dims.HET_DIM, 32, enc_out)),
        "dec": _mlp_params(k2, (dims.LATENT_DIM, 32, dims.HET_DIM)),
    }


def ae_encode(kind, params, x):
    out = _mlp(params["enc"], x)
    if kind == "vae":
        return out[:, : dims.LATENT_DIM]  # mean path at inference
    return out


def ae_loss(kind, params, x, eps):
    out = _mlp(params["enc"], x)
    if kind == "vae":
        mu = out[:, : dims.LATENT_DIM]
        logvar = jnp.clip(out[:, dims.LATENT_DIM :], -8.0, 8.0)
        zlat = mu + eps * jnp.exp(0.5 * logvar)
        recon = _mlp(params["dec"], zlat)
        kl = -0.5 * jnp.mean(1.0 + logvar - mu**2 - jnp.exp(logvar))
        return jnp.mean((recon - x) ** 2) + 1e-3 * kl
    recon = _mlp(params["dec"], out)
    return jnp.mean((recon - x) ** 2)


def make_ae_fns(kind):
    template = init_ae(kind, jax.random.PRNGKey(0))
    flat0, unravel = ravel_pytree(template)
    theta_len = flat0.shape[0]

    def init_flat(seed):
        return (ravel_pytree(init_ae(kind, jax.random.PRNGKey(seed)))[0],)

    def encode_flat(theta, x):
        return (ae_encode(kind, unravel(theta), x),)

    def train_flat(theta, m, v, step, x, eps):
        loss, g = jax.value_and_grad(lambda th: ae_loss(kind, unravel(th), x, eps))(theta)
        theta2, m2, v2 = adam_update(theta, m, v, g, step, dims.AE_LR)
        return theta2, m2, v2, loss

    return theta_len, init_flat, encode_flat, train_flat
