"""Fixed dimensions shared across L1/L2 and (via artifacts/manifest.json)
with the Rust L3 coordinator.

Rust-side mirrors (checked at runtime against the manifest):
  * DMAP_*   -> rust/src/sparse/features.rs
  * MAPPED_DIM / HET_DIM / FA_DIM -> rust/src/config/encode.rs
"""

# Density-map rasterisation of the sparsity pattern (C, H, W).
DMAP_C = 4
DMAP_H = 32
DMAP_W = 32

# Configuration encodings.
MAPPED_DIM = 53  # homogeneous (configuration-mapper input), paper Table 6
HET_DIM = 16     # heterogeneous (latent-encoder input)
FA_DIM = 30      # feature-augmentation baseline input

# Embeddings (paper Table 6: matrix 128, config 64, latent 64).
EMBED_DIM = 128
CFG_EMBED = 64
LATENT_DIM = 64

# Featurizer conv pyramid: 4 blocks x 3 convs = 12 layers (paper Fig 3),
# channels rising across blocks (vs. WACO's fixed width).
FEAT_BLOCKS = ((8, 8, 16), (16, 16, 32), (32, 32, 64), (64, 64, 64))
# WACO baseline featurizer: fixed-width, no channel growth.
WACO_CHANNELS = 16
WACO_LAYERS = 12

# Batch shapes baked into the AOT artifacts (Rust pads partial batches).
FEAT_B = 4    # matrices per featurize call
SCORE_B = 64  # (config, matrix-embedding) rows per score call
TRAIN_B = 8   # ranking pairs per train step

# Training hyperparameters (paper Appendix F).
MARGIN = 1.0
LR = 1e-4
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
AE_LR = 1e-3
