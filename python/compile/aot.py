"""AOT lowering: every L2 entry point -> HLO *text* + manifest.json.

Run once by `make artifacts`; the Rust runtime then loads/compiles the
HLO through PJRT and Python never appears on the request path.

HLO text (NOT serialized HloModuleProto) is the interchange format: the
`xla` crate's xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit
instruction ids); the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import dims, model

jax.config.update("jax_platform_name", "cpu")


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def spec_json(name, s):
    return {"name": name, "shape": list(s.shape), "dtype": str(s.dtype)}


def lower_entry(out_dir, manifest, name, fn, arg_specs):
    """Lower `fn` at `arg_specs` and record it in the manifest."""
    lowered = jax.jit(fn, keep_unused=True).lower(*[s for _, s in arg_specs])
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    # Output specs from the lowered computation's result shapes.
    out_specs = [
        {"shape": list(x.shape), "dtype": str(x.dtype)}
        for x in jax.eval_shape(fn, *[s for _, s in arg_specs])
    ]
    manifest["artifacts"][name] = {
        "file": fname,
        "inputs": [spec_json(n, s) for n, s in arg_specs],
        "outputs": out_specs,
    }
    print(f"  {name}: {len(text) / 1024:.0f} KiB, {len(arg_specs)} inputs")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--variants",
        default=",".join(model.VARIANTS),
        help="comma-separated model variants to lower",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {
        "dims": {
            "DMAP_C": dims.DMAP_C,
            "DMAP_H": dims.DMAP_H,
            "DMAP_W": dims.DMAP_W,
            "MAPPED_DIM": dims.MAPPED_DIM,
            "HET_DIM": dims.HET_DIM,
            "FA_DIM": dims.FA_DIM,
            "EMBED_DIM": dims.EMBED_DIM,
            "LATENT_DIM": dims.LATENT_DIM,
            "FEAT_B": dims.FEAT_B,
            "SCORE_B": dims.SCORE_B,
            "TRAIN_B": dims.TRAIN_B,
            "MARGIN": dims.MARGIN,
            "LR": dims.LR,
        },
        "theta_len": {},
        "artifacts": {},
    }

    dmap_feat = f32(dims.FEAT_B, dims.DMAP_C, dims.DMAP_H, dims.DMAP_W)
    dmap_train = f32(dims.TRAIN_B, dims.DMAP_C, dims.DMAP_H, dims.DMAP_W)

    for variant in args.variants.split(","):
        print(f"[aot] lowering variant {variant!r}")
        cfg_dim = dims.FA_DIM if variant == "waco_fa" else dims.MAPPED_DIM
        theta_len, init_f, feat_f, scorec_f, train_f = model.make_flat_fns(variant)
        manifest["theta_len"][variant] = theta_len
        th = f32(theta_len)
        lower_entry(args.out, manifest, f"{variant}_init", init_f, [("seed", i32())])
        lower_entry(
            args.out,
            manifest,
            f"{variant}_featurize",
            feat_f,
            [("theta", th), ("dmap", dmap_feat)],
        )
        lower_entry(
            args.out,
            manifest,
            f"{variant}_score_cached",
            scorec_f,
            [
                ("theta", th),
                ("s", f32(dims.SCORE_B, dims.EMBED_DIM)),
                ("cfg", f32(dims.SCORE_B, cfg_dim)),
                ("z", f32(dims.SCORE_B, dims.LATENT_DIM)),
            ],
        )
        lower_entry(
            args.out,
            manifest,
            f"{variant}_train",
            train_f,
            [
                ("theta", th),
                ("m", th),
                ("v", th),
                ("step", f32()),
                ("dmap", dmap_train),
                ("cfg_a", f32(dims.TRAIN_B, cfg_dim)),
                ("z_a", f32(dims.TRAIN_B, dims.LATENT_DIM)),
                ("cfg_b", f32(dims.TRAIN_B, cfg_dim)),
                ("z_b", f32(dims.TRAIN_B, dims.LATENT_DIM)),
                ("sign", f32(dims.TRAIN_B)),
                ("weight", f32(dims.TRAIN_B)),
            ],
        )

    for kind in model.AE_KINDS:
        print(f"[aot] lowering autoencoder {kind!r}")
        theta_len, init_f, enc_f, train_f = model.make_ae_fns(kind)
        manifest["theta_len"][kind] = theta_len
        th = f32(theta_len)
        lower_entry(args.out, manifest, f"{kind}_init", init_f, [("seed", i32())])
        lower_entry(
            args.out,
            manifest,
            f"{kind}_encode",
            enc_f,
            [("theta", th), ("x", f32(dims.SCORE_B, dims.HET_DIM))],
        )
        lower_entry(
            args.out,
            manifest,
            f"{kind}_train",
            train_f,
            [
                ("theta", th),
                ("m", th),
                ("v", th),
                ("step", f32()),
                ("x", f32(dims.SCORE_B, dims.HET_DIM)),
                ("eps", f32(dims.SCORE_B, dims.LATENT_DIM)),
            ],
        )

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {len(manifest['artifacts'])} artifacts + manifest.json to {args.out}")


if __name__ == "__main__":
    main()
