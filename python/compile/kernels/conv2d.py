"""L1: 2-D convolution lowered to im2col + the Pallas matmul kernel.

The paper's featurizer is a sparse CNN; our hardware adaptation keeps
the conv-pyramid topology but runs it dense over density maps, and maps
the convolution onto the MXU-friendly primitive we actually have: a
tiled matmul. im2col is pure data movement (shift-and-stack, cheap and
fusable by XLA); 100% of the FLOPs go through `matmul_fused`, so both
forward and backward hit the Pallas tile kernel.

Layout: NHWC (channels-last), SAME padding, stride 1.
"""

import jax.numpy as jnp

from .matmul import matmul_fused


def im2col(x, ksize: int):
    """[B, H, W, C] -> [B, H, W, C*ksize*ksize] patch tensor (SAME)."""
    b, h, w, c = x.shape
    r = ksize // 2
    xp = jnp.pad(x, ((0, 0), (r, r), (r, r), (0, 0)))
    shifts = []
    for dy in range(ksize):
        for dx in range(ksize):
            shifts.append(xp[:, dy : dy + h, dx : dx + w, :])
    return jnp.concatenate(shifts, axis=-1)


def conv2d(x, w, b, relu=True):
    """SAME conv: x [B,H,W,Cin], w [k,k,Cin,Cout], b [Cout]."""
    kh, kw, cin, cout = w.shape
    assert kh == kw, "square kernels only"
    bsz, h, wd, c = x.shape
    assert c == cin, f"channel mismatch {c} != {cin}"
    patches = im2col(x, kh)  # [B,H,W,Cin*k*k] — note shift-major order
    flat = patches.reshape(bsz * h * wd, cin * kh * kw)
    # Weight must match the patch ordering: (dy, dx, cin) -> rows.
    wflat = w.transpose(0, 1, 2, 3).reshape(kh * kw * cin, cout)
    out = matmul_fused(flat, wflat, b, relu)
    return out.reshape(bsz, h, wd, cout)


def maxpool2x2(x):
    """[B,H,W,C] -> [B,H/2,W/2,C] max pooling (H, W even)."""
    b, h, w, c = x.shape
    assert h % 2 == 0 and w % 2 == 0, f"odd spatial dims {h}x{w}"
    x = x.reshape(b, h // 2, 2, w // 2, 2, c)
    return jnp.max(x, axis=(2, 4))


def global_avg_pool(x):
    """[B,H,W,C] -> [B,C]."""
    return jnp.mean(x, axis=(1, 2))
