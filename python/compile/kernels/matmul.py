"""L1 Pallas kernel: tiled fused matmul (+bias, +optional ReLU).

This is the compute hot-spot of the whole cost model — every MLP layer
and (via im2col) every featurizer convolution lowers to it.

TPU/MXU thinking (DESIGN.md §Hardware-Adaptation): the 128x128 output
tile matches the MXU systolic array; the full-K operand panels live in
VMEM for the duration of a tile (VMEM budget at our shapes: the largest
K in the model is C*9 <= 1152 for conv im2col and 256 for the predictor,
so an (128, K) f32 LHS tile tops out at 128*1152*4 B = 576 KiB and the
(K, 128) RHS at the same — comfortably inside a 16 MiB VMEM alongside
the 64 KiB accumulator, no K-loop double-buffering needed). Grid order
is output-stationary: each (i, j) step writes its tile exactly once, so
HBM<->VMEM traffic is one read of each operand panel row/col per tile
plus one accumulator write — the BlockSpec equivalent of the
threadblock-resident accumulation a CUDA kernel would use.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers the same schedule to plain HLO.

The backward pass reuses the SAME kernel (transposed operands), wired up
with `jax.custom_vjp`, so training traffic also flows through Pallas.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output tile: MXU-shaped by default (128×128). On the CPU-interpret
# path the per-grid-step dispatch overhead dominates tiny tiles, so the
# §Perf pass can widen the M tile via env (COGNATE_BLOCK_M) at AOT time —
# on a real TPU 128 stays optimal for the systolic array, and the VMEM
# budget analysis below holds for either setting.
import os

BLOCK_M = int(os.environ.get("COGNATE_BLOCK_M", "128"))
BLOCK_N = int(os.environ.get("COGNATE_BLOCK_N", "128"))


def _mm_kernel(relu: bool, x_ref, w_ref, b_ref, o_ref):
    """One (BLOCK_M, BLOCK_N) output tile: full-K panels in VMEM."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def _matmul_raw(x, w, b, relu: bool):
    """Pallas tiled matmul: x [M, K] @ w [K, N] + b [N]."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} != {k2}"
    assert b.shape == (n,), f"bias shape {b.shape}"
    bm = min(BLOCK_M, m)
    bn = min(BLOCK_N, n)
    xp = _pad_to(x, 0, bm)
    wp = _pad_to(w, 1, bn)
    bp = _pad_to(b.reshape(1, n), 1, bn)
    grid = (xp.shape[0] // bm, wp.shape[1] // bn)
    out = pl.pallas_call(
        functools.partial(_mm_kernel, relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def matmul_fused(x, w, b, relu=False):
    """Fused `relu?(x @ w + b)` through the Pallas tile kernel.

    Differentiable: the VJP routes both gradient matmuls through the same
    kernel (dx = g @ w.T, dw = x.T @ g).
    """
    return _matmul_raw(x, w, b, relu)


def _mm_fwd(x, w, b, relu):
    out = _matmul_raw(x, w, b, relu)
    return out, (x, w, out if relu else None)


def _mm_bwd(relu, res, g):
    x, w, out = res
    if relu:
        g = jnp.where(out > 0.0, g, 0.0)
    dx = _matmul_raw(g, w.T, jnp.zeros((w.shape[0],), jnp.float32), False)
    dw = _matmul_raw(x.T, g, jnp.zeros((w.shape[1],), jnp.float32), False)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


matmul_fused.defvjp(_mm_fwd, _mm_bwd)


def linear(params, x, relu=False):
    """Convenience: apply a {'w','b'} layer dict via the Pallas kernel."""
    return matmul_fused(x, params["w"], params["b"], relu)
