"""Pure-jnp oracles for every L1 Pallas kernel.

pytest (python/tests/) asserts the Pallas implementations match these to
tight tolerance across shape/dtype sweeps — THE correctness signal for
layer 1.
"""

import jax.numpy as jnp
from jax import lax


def matmul_ref(x, w, b, relu=False):
    out = x @ w + b[None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def conv2d_ref(x, w, b, relu=True):
    """NHWC SAME conv via lax.conv_general_dilated."""
    out = lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    out = out + b[None, None, None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def maxpool2x2_ref(x):
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, 2, 2, 1),
        window_strides=(1, 2, 2, 1),
        padding="VALID",
    )


def ranking_loss_ref(ra, rb, sign, weight, margin=1.0):
    per_pair = weight * jnp.maximum(0.0, margin - sign * (ra - rb))
    return jnp.sum(per_pair) / jnp.maximum(jnp.sum(weight), 1.0)
