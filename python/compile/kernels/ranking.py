"""L1 Pallas kernel: pairwise margin ranking loss (Appendix A.4).

Per pair (a, b) with true-order sign s in {-1, 0, +1} and sample weight
w (0 for padded rows of a fixed-size batch):

    l_i = w_i * max(0, margin - s_i * (ra_i - rb_i))

The kernel emits the per-pair hinge vector; the (scalar) mean is taken
in jnp so the custom VJP stays a clean elementwise rule:

    d l_i / d ra_i = -w_i * s_i * [hinge active]      (and +ws for rb).

Single-block kernel: the batch is tiny (TRAIN_B pairs), so one VMEM
block holds everything — no grid needed.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hinge_kernel(margin: float, ra_ref, rb_ref, s_ref, w_ref, o_ref):
    diff = ra_ref[...] - rb_ref[...]
    o_ref[...] = w_ref[...] * jnp.maximum(0.0, margin - s_ref[...] * diff)


def _hinge_raw(ra, rb, sign, weight, margin: float):
    (n,) = ra.shape
    return pl.pallas_call(
        functools.partial(_hinge_kernel, margin),
        grid=(1,),
        in_specs=[pl.BlockSpec((n,), lambda i: (0,))] * 4,
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(ra, rb, sign, weight)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def pairwise_hinge(ra, rb, sign, weight, margin=1.0):
    """Per-pair weighted hinge vector, differentiable in ra/rb."""
    return _hinge_raw(ra, rb, sign, weight, margin)


def _fwd(ra, rb, sign, weight, margin):
    out = _hinge_raw(ra, rb, sign, weight, margin)
    active = (out > 0.0).astype(jnp.float32)
    return out, (sign, weight, active)


def _bwd(margin, res, g):
    sign, weight, active = res
    dra = -g * weight * sign * active
    return dra, -dra, None, None


pairwise_hinge.defvjp(_fwd, _bwd)


def ranking_loss(ra, rb, sign, weight, margin=1.0):
    """Mean weighted hinge over the (non-padded) pairs of a batch."""
    per_pair = pairwise_hinge(ra, rb, sign, weight, margin)
    return jnp.sum(per_pair) / jnp.maximum(jnp.sum(weight), 1.0)
