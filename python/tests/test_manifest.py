"""Manifest ↔ dims consistency: the contract between aot.py and the
Rust runtime. Runs against artifacts/ if present (made by `make
artifacts`); otherwise validates the spec-generation logic in-process.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import dims, model

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ARTIFACTS, "manifest.json")


@pytest.mark.skipif(not os.path.exists(MANIFEST), reason="run `make artifacts` first")
def test_manifest_matches_dims():
    m = json.load(open(MANIFEST))
    d = m["dims"]
    assert d["DMAP_C"] == dims.DMAP_C
    assert d["DMAP_H"] == dims.DMAP_H
    assert d["DMAP_W"] == dims.DMAP_W
    assert d["MAPPED_DIM"] == dims.MAPPED_DIM == 53
    assert d["HET_DIM"] == dims.HET_DIM
    assert d["FA_DIM"] == dims.FA_DIM
    assert d["LATENT_DIM"] == dims.LATENT_DIM
    assert d["TRAIN_B"] == dims.TRAIN_B


@pytest.mark.skipif(not os.path.exists(MANIFEST), reason="run `make artifacts` first")
def test_manifest_covers_all_variants_and_files_exist():
    m = json.load(open(MANIFEST))
    for v in model.VARIANTS:
        assert v in m["theta_len"], f"missing theta_len for {v}"
        for entry in ("init", "featurize", "score_cached", "train"):
            name = f"{v}_{entry}"
            assert name in m["artifacts"], f"missing artifact {name}"
            f = m["artifacts"][name]["file"]
            assert os.path.exists(os.path.join(ARTIFACTS, f)), f"missing file {f}"
    for kind in model.AE_KINDS:
        for entry in ("init", "encode", "train"):
            assert f"{kind}_{entry}" in m["artifacts"]


@pytest.mark.skipif(not os.path.exists(MANIFEST), reason="run `make artifacts` first")
def test_manifest_shapes_match_model():
    m = json.load(open(MANIFEST))
    for v in model.VARIANTS:
        theta_len = model.make_flat_fns(v)[0]
        assert m["theta_len"][v] == theta_len, f"theta_len drift for {v}"
        tr = m["artifacts"][f"{v}_train"]
        # θ in, θ out, same length; loss scalar last.
        assert tr["inputs"][0]["shape"] == [theta_len]
        assert tr["outputs"][0]["shape"] == [theta_len]
        assert tr["outputs"][-1]["shape"] == []
        cfg_dim = dims.FA_DIM if v == "waco_fa" else dims.MAPPED_DIM
        assert tr["inputs"][5]["shape"] == [dims.TRAIN_B, cfg_dim]


@pytest.mark.skipif(not os.path.exists(MANIFEST), reason="run `make artifacts` first")
def test_hlo_text_artifacts_are_parseable_hlo():
    m = json.load(open(MANIFEST))
    # Spot-check: files are non-trivial HLO text with an ENTRY computation.
    for name in ("cognate_train", "ae_encode", "waco_fa_score_cached"):
        path = os.path.join(ARTIFACTS, m["artifacts"][name]["file"])
        text = open(path).read()
        assert "ENTRY" in text, f"{name} does not look like HLO text"
        assert "f32" in text


def test_train_step_consumes_all_inputs_even_when_unused():
    """Regression for the dropped-parameter bug: lowering must keep
    unused inputs (e.g. eps in the plain AE) in the HLO signature."""
    from compile.aot import to_hlo_text

    def fn(a, b):  # b unused
        return (a * 2.0,)

    spec = jax.ShapeDtypeStruct((4,), jnp.float32)
    lowered = jax.jit(fn, keep_unused=True).lower(spec, spec)
    text = to_hlo_text(lowered)
    # Both parameters present in the entry signature.
    assert text.count("parameter(0)") == 1
    assert text.count("parameter(1)") == 1
