"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.conv2d import conv2d, global_avg_pool, im2col, maxpool2x2
from compile.kernels.matmul import matmul_fused
from compile.kernels.ranking import pairwise_hinge, ranking_loss

jax.config.update("jax_platform_name", "cpu")


def rnd(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (4, 7, 9), (128, 64, 128), (130, 72, 257), (8, 1152, 16)])
@pytest.mark.parametrize("relu", [False, True])
def test_matmul_matches_ref(m, k, n, relu):
    x, w, b = rnd(0, m, k), rnd(1, k, n), rnd(2, n)
    got = matmul_fused(x, w, b, relu)
    want = ref.matmul_ref(x, w, b, relu)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 150),
    k=st.integers(1, 96),
    n=st.integers(1, 150),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_sweep(m, k, n, relu, seed):
    kx, kw, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.uniform(kx, (m, k), jnp.float32, -2.0, 2.0)
    w = jax.random.uniform(kw, (k, n), jnp.float32, -2.0, 2.0)
    b = jax.random.uniform(kb, (n,), jnp.float32, -2.0, 2.0)
    np.testing.assert_allclose(
        matmul_fused(x, w, b, relu), ref.matmul_ref(x, w, b, relu), rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("relu", [False, True])
def test_matmul_grads_match_ref(relu):
    x, w, b = rnd(3, 17, 23), rnd(4, 23, 11), rnd(5, 11)

    def loss_pallas(x, w, b):
        return jnp.sum(matmul_fused(x, w, b, relu) ** 2)

    def loss_ref(x, w, b):
        return jnp.sum(ref.matmul_ref(x, w, b, relu) ** 2)

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gp, gr):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)


def test_matmul_under_jit():
    x, w, b = rnd(6, 33, 8), rnd(7, 8, 5), rnd(8, 5)
    got = jax.jit(lambda x: matmul_fused(x, w, b, True))(x)
    np.testing.assert_allclose(got, ref.matmul_ref(x, w, b, True), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# conv2d / pooling
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ksize", [3, 5])
@pytest.mark.parametrize("cin,cout", [(1, 4), (4, 8), (8, 16)])
def test_conv2d_matches_ref(ksize, cin, cout):
    x = rnd(10, 2, 16, 16, cin)
    w = rnd(11, ksize, ksize, cin, cout) * 0.3
    b = rnd(12, cout) * 0.1
    got = conv2d(x, w, b, relu=True)
    want = ref.conv2d_ref(x, w, b, relu=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    h=st.sampled_from([4, 8, 12]),
    cin=st.integers(1, 6),
    cout=st.integers(1, 6),
    ksize=st.sampled_from([3, 5]),
    seed=st.integers(0, 1000),
)
def test_conv2d_hypothesis_sweep(h, cin, cout, ksize, seed):
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k0, (1, h, h, cin), jnp.float32)
    w = jax.random.normal(k1, (ksize, ksize, cin, cout), jnp.float32) * 0.2
    b = jax.random.normal(k2, (cout,), jnp.float32) * 0.1
    np.testing.assert_allclose(
        conv2d(x, w, b, False), ref.conv2d_ref(x, w, b, False), rtol=1e-4, atol=1e-4
    )


def test_conv2d_grad_matches_ref():
    x = rnd(13, 1, 8, 8, 3)
    w = rnd(14, 3, 3, 3, 5) * 0.3
    b = jnp.zeros(5)
    gp = jax.grad(lambda w: jnp.sum(conv2d(x, w, b, True) ** 2))(w)
    gr = jax.grad(lambda w: jnp.sum(ref.conv2d_ref(x, w, b, True) ** 2))(w)
    np.testing.assert_allclose(gp, gr, rtol=1e-3, atol=1e-3)


def test_im2col_center_shift_identity():
    # The centre shift of im2col is the input itself.
    x = rnd(15, 1, 6, 6, 2)
    patches = im2col(x, 3)
    centre = patches[..., 4 * 2 : 5 * 2]  # shift (dy=1, dx=1), cin=2
    np.testing.assert_allclose(centre, x)


def test_maxpool_matches_ref():
    x = rnd(16, 3, 8, 8, 4)
    np.testing.assert_allclose(maxpool2x2(x), ref.maxpool2x2_ref(x))


def test_global_avg_pool():
    x = jnp.ones((2, 4, 4, 3)) * jnp.arange(1.0, 4.0)[None, None, None, :]
    np.testing.assert_allclose(global_avg_pool(x), jnp.tile(jnp.arange(1.0, 4.0), (2, 1)))


# ---------------------------------------------------------------------------
# ranking loss
# ---------------------------------------------------------------------------


def test_ranking_matches_ref():
    ra, rb = rnd(20, 16), rnd(21, 16)
    sign = jnp.sign(rnd(22, 16))
    weight = (rnd(23, 16) > 0).astype(jnp.float32)
    got = ranking_loss(ra, rb, sign, weight, 1.0)
    want = ref.ranking_loss_ref(ra, rb, sign, weight, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_ranking_grad_matches_ref():
    ra, rb = rnd(24, 12), rnd(25, 12)
    sign = jnp.sign(rnd(26, 12))
    weight = jnp.ones(12)
    gp = jax.grad(lambda a, b: ranking_loss(a, b, sign, weight, 1.0), argnums=(0, 1))(ra, rb)
    gr = jax.grad(lambda a, b: ref.ranking_loss_ref(a, b, sign, weight, 1.0), argnums=(0, 1))(
        ra, rb
    )
    for a, e in zip(gp, gr):
        np.testing.assert_allclose(a, e, rtol=1e-6, atol=1e-6)


def test_ranking_padded_rows_no_gradient():
    ra, rb = rnd(27, 8), rnd(28, 8)
    sign = jnp.ones(8)
    weight = jnp.array([1, 1, 1, 1, 0, 0, 0, 0], jnp.float32)
    g = jax.grad(lambda a: ranking_loss(a, rb, sign, weight, 1.0))(ra)
    assert np.all(np.asarray(g[4:]) == 0.0), "padded pairs must not leak gradient"


def test_hinge_satisfied_pairs_zero():
    # Well-separated in the right direction → zero loss.
    ra = jnp.array([5.0, -5.0])
    rb = jnp.array([0.0, 0.0])
    sign = jnp.array([1.0, -1.0])
    w = jnp.ones(2)
    assert float(ranking_loss(ra, rb, sign, w, 1.0)) == 0.0
    per = pairwise_hinge(ra, rb, sign, w, 1.0)
    np.testing.assert_allclose(per, jnp.zeros(2))
