"""L2 model tests: shapes, training-step behaviour, variant semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import dims, model

jax.config.update("jax_platform_name", "cpu")


import functools


@functools.lru_cache(maxsize=None)
def flat_fns(variant):
    """make_flat_fns with every entry point jitted (mirrors AOT usage —
    eager interpret-mode Pallas is orders of magnitude slower)."""
    theta_len, init_f, feat_f, scorec_f, train_f = model.make_flat_fns(variant)
    return (theta_len, jax.jit(init_f), jax.jit(feat_f), jax.jit(scorec_f), jax.jit(train_f))


@functools.lru_cache(maxsize=None)
def ae_fns(kind):
    theta_len, init_f, enc_f, train_f = model.make_ae_fns(kind)
    return (theta_len, jax.jit(init_f), jax.jit(enc_f), jax.jit(train_f))


def batch(variant, b, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    cfg_dim = dims.FA_DIM if variant == "waco_fa" else dims.MAPPED_DIM
    return {
        "dmap": jax.random.uniform(ks[0], (b, dims.DMAP_C, dims.DMAP_H, dims.DMAP_W)),
        "cfg_a": jax.random.uniform(ks[1], (b, cfg_dim)),
        "z_a": jax.random.normal(ks[2], (b, dims.LATENT_DIM)),
        "cfg_b": jax.random.uniform(ks[3], (b, cfg_dim)),
        "z_b": jax.random.normal(ks[4], (b, dims.LATENT_DIM)),
        "sign": jnp.sign(jax.random.normal(ks[5], (b,))),
        "weight": jnp.ones((b,)),
    }


@pytest.mark.parametrize("variant", model.VARIANTS)
def test_shapes_all_variants(variant):
    theta_len, init_f, feat_f, scorec_f, _ = flat_fns(variant)
    (theta,) = init_f(0)
    assert theta.shape == (theta_len,)
    assert bool(jnp.all(jnp.isfinite(theta)))
    b = batch(variant, dims.FEAT_B)
    (s,) = feat_f(theta, b["dmap"])
    assert s.shape == (dims.FEAT_B, dims.EMBED_DIM)
    bb = batch(variant, dims.SCORE_B)
    s_big = jnp.tile(s[:1], (dims.SCORE_B, 1))
    (scores,) = scorec_f(theta, s_big, bb["cfg_a"], bb["z_a"])
    assert scores.shape == (dims.SCORE_B,)
    assert bool(jnp.all(jnp.isfinite(scores)))


@pytest.mark.parametrize("variant", ["cognate", "waco_fm"])
def test_train_step_decreases_loss(variant):
    theta_len, init_f, _, _, train_f = flat_fns(variant)
    (theta,) = init_f(1)
    m = jnp.zeros(theta_len)
    v = jnp.zeros(theta_len)
    b = batch(variant, dims.TRAIN_B, seed=7)
    losses = []
    for step in range(1, 16):
        theta, m, v, loss = train_f(
            theta, m, v, jnp.float32(step), b["dmap"], b["cfg_a"], b["z_a"],
            b["cfg_b"], b["z_b"], b["sign"], b["weight"],
        )
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], f"no learning: {losses[0]} -> {losses[-1]}"


def test_init_seed_sensitivity():
    _, init_f, _, _, _ = flat_fns("cognate")
    (t0,) = init_f(0)
    (t0b,) = init_f(0)
    (t1,) = init_f(1)
    np.testing.assert_allclose(t0, t0b)
    assert not np.allclose(t0, t1)


def test_noife_ignores_dmap():
    # Without the input featurizer, scores cannot depend on the matrix.
    theta_len, init_f, feat_f, scorec_f, _ = flat_fns("noife")
    (theta,) = init_f(3)
    b1 = batch("noife", dims.FEAT_B, seed=1)
    b2 = batch("noife", dims.FEAT_B, seed=2)
    (s1,) = feat_f(theta, b1["dmap"])
    (s2,) = feat_f(theta, b2["dmap"])
    np.testing.assert_allclose(s1, s2)  # both zero


def test_nole_ignores_latent():
    theta_len, init_f, feat_f, scorec_f, _ = flat_fns("nole")
    (theta,) = init_f(4)
    b = batch("nole", dims.SCORE_B, seed=5)
    s = jnp.zeros((dims.SCORE_B, dims.EMBED_DIM))
    (r1,) = scorec_f(theta, s, b["cfg_a"], b["z_a"])
    (r2,) = scorec_f(theta, s, b["cfg_a"], b["z_b"])
    np.testing.assert_allclose(r1, r2)


def test_cognate_uses_all_inputs():
    theta_len, init_f, feat_f, scorec_f, _ = flat_fns("cognate")
    (theta,) = init_f(5)
    b = batch("cognate", dims.SCORE_B, seed=6)
    s = jax.random.normal(jax.random.PRNGKey(8), (dims.SCORE_B, dims.EMBED_DIM))
    (r0,) = scorec_f(theta, s, b["cfg_a"], b["z_a"])
    (r_cfg,) = scorec_f(theta, s, b["cfg_b"], b["z_a"])
    (r_z,) = scorec_f(theta, s, b["cfg_a"], b["z_b"])
    (r_s,) = scorec_f(theta, s * 2.0, b["cfg_a"], b["z_a"])
    assert not np.allclose(r0, r_cfg)
    assert not np.allclose(r0, r_z)
    assert not np.allclose(r0, r_s)


def test_featurize_distinguishes_matrices():
    _, init_f, feat_f, _, _ = flat_fns("cognate")
    (theta,) = init_f(6)
    d1 = jax.random.uniform(jax.random.PRNGKey(1), (dims.FEAT_B, dims.DMAP_C, dims.DMAP_H, dims.DMAP_W))
    (s,) = feat_f(theta, d1)
    # distinct rows for distinct maps
    assert not np.allclose(s[0], s[1])


@pytest.mark.parametrize("kind", model.AE_KINDS)
def test_autoencoder_learns_reconstruction(kind):
    theta_len, init_f, enc_f, train_f = ae_fns(kind)
    (theta,) = init_f(0)
    m = jnp.zeros(theta_len)
    v = jnp.zeros(theta_len)
    key = jax.random.PRNGKey(9)
    # Binary-ish het vectors like the real encoding.
    x = (jax.random.uniform(key, (dims.SCORE_B, dims.HET_DIM)) > 0.5).astype(jnp.float32)
    first = None
    loss = None
    for step in range(1, 121):
        eps = jax.random.normal(jax.random.fold_in(key, step), (dims.SCORE_B, dims.LATENT_DIM))
        theta, m, v, loss = train_f(theta, m, v, jnp.float32(step), x, eps)
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.5, f"{kind}: {first} -> {float(loss)}"
    (z,) = enc_f(theta, x)
    assert z.shape == (dims.SCORE_B, dims.LATENT_DIM)
    assert bool(jnp.all(jnp.isfinite(z)))


def test_theta_lengths_differ_across_variants():
    lens = {v: model.make_flat_fns(v)[0] for v in ("cognate", "noife", "waco_fa")}
    assert lens["cognate"] != lens["noife"]
    assert len(set(lens.values())) == 3
